"""Executor implementations for the tri-store physical operators.

Each store engine owns its impl table (``engines.py``); importing this
module registers the relational / graph / text implementations plus the two
cross-engine transfer realizations.  Every relational value — plan input,
intermediate, or output — is a :class:`~repro.stores.bounded.BoundedRel`
(a registered pytree: struct-of-arrays columns + validity + traced row
count), so a whole tri-model plan stays jittable end to end and the
*cardinality* of every intermediate is a first-class runtime value: masks
are no longer rel-engine-private, and the executor can observe
``count/capacity`` per site for selectivity feedback
(``ExecContext.aux["count_sink"]``, see ``PlannedFunction.observe``).

The relational ops are factored as pure *step functions* shared by the
per-op impls and the fused-chain impls (``rel_fused_*``): a fused chain
executes exactly the same step functions in the same order, so fusion is
bitwise-neutral by construction.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..core.engines import get_engine
from ..core.feedback import filter_site, sel_mask_site
from ..core.ledger import default_ledger
from .base import GRAPH_ENGINE, REL_ENGINE, TEXT_ENGINE
from .bounded import BoundedRel, as_bounded, compact_rel
from .column_store import (filter_mask, group_agg, hash_join,
                           hash_join_nonunique)
from .graph_store import (expand_frontier, expand_frontier_blockskip,
                          pagerank, triangle_count)
from .masked_kernels import (compact_prefix_pallas, join_probe_pallas,
                             masked_segment_agg_pallas, masked_tfidf_pallas)
from .sharded import (_shardable, coll_all_to_all_bytes, coll_allgather_bytes,
                      coll_psum_bytes, data_axis_size, sharded_broadcast_join,
                      sharded_count, sharded_expand, sharded_group_agg,
                      sharded_pagerank, sharded_partitioned_join,
                      sharded_tfidf_topk)
from .text_store import (masked_topk, tfidf_scores, tfidf_topk,
                         tfidf_topk_blockskip, tfidf_topk_masked)

_XLA = get_engine("xla")
_PALLAS = get_engine("pallas")


def _record_count(ctx, site, count, capacity):
    """Cardinality observation hook: when the caller planted a
    ``count_sink`` (PlannedFunction.observe runs plans eagerly with one),
    append this site's observed (count, capacity).  Counts stay on device
    — the sink drains in **one** ``device_get`` per run
    (``tracing.resolve_counts``), never per site."""
    sink = None if ctx is None else ctx.aux.get("count_sink")
    if sink is not None:
        sink.append((site, count, capacity))


def _annotate(ctx, **attrs):
    """Runtime-attribution hook: when the executor traced this op
    (``ExecContext.tracer``), report which dist strategy the impl actually
    dispatched and the per-shard collective bytes its kernel moves.  A
    cheap no-op when tracing is off."""
    tr = None if ctx is None else getattr(ctx, "tracer", None)
    if tr is not None:
        tr.annotate(**attrs)


# --------------------------------------------------------------------------
# relational engine: step functions + per-op impls
# --------------------------------------------------------------------------


def _step_rel_scan(tbl, attrs, ctx=None):
    rel = as_bounded(tbl)
    cols = attrs.get("cols")
    if cols:
        return rel.with_cols({c: rel.cols[c] for c in cols})
    return rel


def _step_rel_filter(tbl, attrs, ctx=None):
    rel = as_bounded(tbl)
    m = filter_mask(rel.cols[attrs["col"]], attrs["cmp"], attrs["value"])
    out = rel.narrowed(m)
    if ctx is not None and ctx.aux.get("count_sink") is not None:
        # record the *marginal* selectivity (survivors over the rows this
        # filter actually saw), not the cumulative count/capacity fraction
        # — estimate_selectivity multiplies marginals along the lineage,
        # so a cumulative observation would double-discount upstream
        # narrowing.  The planner-stamped site (stable across compaction
        # rerouting) wins over the self-derived one.
        site = attrs.get("site")
        if site is None:
            site = filter_site(attrs, rel.col_names(), rel.capacity)
        count = out.count
        mesh = getattr(ctx, "mesh", None)
        if (attrs.get("dist") == "row"
                and _shardable(mesh, out.valid.shape[0])):
            # shard-local survivor count + psum: integer addition is
            # associative, so SelectivityFeedback sees the exact count
            count = sharded_count(out.valid, mesh)
            _annotate(ctx, dist="row", coll="psum",
                      coll_bytes=coll_psum_bytes(4, data_axis_size(mesh)))
        _record_count(ctx, tuple(site), count,
                      jnp.maximum(rel.count, 1))
    return out


def _merge_join_cols(left, right, ro, idx):
    """Joined column set: every left column plus the right side's
    non-key, non-colliding columns gathered at ``idx``."""
    cols = dict(left.cols)
    for k, v in right.cols.items():
        if k == ro or k in cols:
            continue
        cols[k] = v[idx]
    return cols


def _step_rel_join(left, right, attrs, ctx=None):
    left, right = as_bounded(left), as_bounded(right)
    lo, ro = attrs["left_on"], attrs["right_on"]
    idx, matched = hash_join(left.cols[lo], right.cols[ro])
    rmask = right.valid[idx]
    cols = _merge_join_cols(left, right, ro, idx)
    valid = left.valid & matched & rmask
    return BoundedRel(cols, valid, None, left.overflow | right.overflow)


def _step_rel_join_probe(left, right, attrs, ctx=None, interpret=True):
    """The Pallas probe realization of ``rel_join``: key equality on the
    MXU against the (expected-count-bounded) build side.  Invalid build
    rows never match, so validity needs no second gather; gathered values
    at unmatched rows differ from the sort-probe path only under
    ``valid=False``, which every consumer weights away."""
    left, right = as_bounded(left), as_bounded(right)
    lo, ro = attrs["left_on"], attrs["right_on"]
    idx, matched = join_probe_pallas(left.cols[lo], right.cols[ro],
                                     right.valid, interpret=interpret)
    cols = _merge_join_cols(left, right, ro, idx)
    valid = left.valid & matched
    return BoundedRel(cols, valid, None, left.overflow | right.overflow)


def _step_bounded_join(left, right, attrs, ctx=None):
    left, right = as_bounded(left), as_bounded(right)
    lo, ro = attrs["left_on"], attrs["right_on"]
    lidx, ridx, valid, count, ovf = hash_join_nonunique(
        left.cols[lo], left.valid, right.cols[ro], right.valid,
        int(attrs["capacity"]))
    gathered = left.with_cols({k: v[lidx] for k, v in left.cols.items()})
    cols = _merge_join_cols(gathered, right, ro, ridx)
    return BoundedRel(cols, valid, count,
                      ovf | left.overflow | right.overflow)


def _step_rel_group_agg(tbl, attrs, ctx=None):
    rel = as_bounded(tbl)
    key = rel.cols[attrs["key"]]
    g = int(attrs["num_groups"])
    mask = rel.valid
    cols = {attrs["key"]: jnp.arange(g, dtype=jnp.int32)}
    for out_name, fn, col in attrs["aggs"]:
        vals = None if fn == "count" else rel.cols[col]
        r = group_agg(vals, key, g, mask, fn)
        if fn == "max":
            # the pair convention collapses into row validity: an
            # all-masked group is an *invalid row* of the output relation
            r, _valid = r
        cols[out_name] = r
    count = group_agg(None, key, g, mask, "count")
    return BoundedRel(cols, count > 0, None, rel.overflow)


def _step_compact(tbl, attrs, ctx=None):
    rel = as_bounded(tbl)
    out = compact_rel(rel, attrs.get("capacity"))
    _record_overflow(ctx, attrs, out)
    return out


def _record_overflow(ctx, attrs, out):
    """Report a compaction site's overflow flag to the observation sink:
    an overflowed bound dropped rows, and the feedback store's
    ``note_overflow`` makes ``choose_compaction`` back off from the site
    on re-plan instead of staying silently lossy."""
    site = attrs.get("site")
    if site is not None:
        _record_count(ctx, ("compact_overflow", tuple(site)),
                      out.overflow, 1)


def _step_compact_pallas(tbl, attrs, ctx=None, interpret=True):
    """Pallas realization of ``compact``: destination positions from an
    XLA prefix sum, the scatter as the one-hot-matmul compaction kernel.
    Bit-exact for float columns; integer columns round-trip through
    float32 (exact below 2^24, which the candidate gate enforces)."""
    rel = as_bounded(tbl)
    cap = int(attrs.get("capacity", rel.capacity))
    cap = max(1, min(cap, rel.capacity))
    keep = rel.valid.astype(jnp.float32)
    pos = jnp.where(rel.valid, jnp.cumsum(rel.valid.astype(jnp.int32)) - 1,
                    -1)
    names = tuple(rel.cols)
    stacked = jnp.stack([rel.cols[n].astype(jnp.float32) for n in names])
    out = compact_prefix_pallas(stacked, pos, keep, out_capacity=cap,
                                interpret=interpret)
    count = jnp.minimum(rel.count, cap).astype(jnp.int32)
    valid = jnp.arange(cap, dtype=jnp.int32) < count
    cols = {}
    for i, n in enumerate(names):
        dt = rel.cols[n].dtype
        v = out[i]
        if jnp.issubdtype(dt, jnp.integer) or dt == jnp.bool_:
            v = jnp.round(v)
        cols[n] = v.astype(dt)
    overflow = rel.overflow | (rel.count > cap)
    out = BoundedRel(cols, valid, count, overflow)
    _record_overflow(ctx, attrs, out)
    return out


_REL_STEPS = {
    "rel_scan": lambda ins, attrs, ctx=None: _step_rel_scan(ins[0], attrs, ctx),
    "rel_filter": lambda ins, attrs, ctx=None: _step_rel_filter(ins[0], attrs,
                                                                ctx),
    "rel_join": lambda ins, attrs, ctx=None: _step_rel_join(ins[0], ins[1],
                                                            attrs, ctx),
    "bounded_join": lambda ins, attrs, ctx=None: _step_bounded_join(
        ins[0], ins[1], attrs, ctx),
    "rel_group_agg": lambda ins, attrs, ctx=None: _step_rel_group_agg(
        ins[0], attrs, ctx),
    "compact": lambda ins, attrs, ctx=None: _step_compact(ins[0], attrs, ctx),
}


def _run_chain(args, chain, ctx=None, *, stop_before_last=False):
    """Execute a ``rel_fused`` step chain over the node's bound inputs."""
    steps = chain[:-1] if stop_before_last else chain
    prev = None
    for op, attrs, srcs, _out_t in steps:
        ins = [prev if s == "prev" else args[int(s)] for s in srcs]
        prev = _REL_STEPS[op](ins, attrs, ctx)
    return prev


@REL_ENGINE.impl("rel_scan_col")
def _i_rel_scan(ctx, args, node):
    return _step_rel_scan(args[0], node.attrs, ctx)


@REL_ENGINE.impl("rel_filter_col")
def _i_rel_filter(ctx, args, node):
    return _step_rel_filter(args[0], node.attrs, ctx)


@REL_ENGINE.impl("rel_hash_join")
def _i_rel_join(ctx, args, node):
    a = node.attrs
    mesh = getattr(ctx, "mesh", None)
    if a.get("dist") == "broadcast":
        left, right = as_bounded(args[0]), as_bounded(args[1])
        if _shardable(mesh, left.capacity):
            # probe side row-partitioned, build side replicated: each shard
            # probes its block against the full build (bitwise = dense)
            idx, matched = sharded_broadcast_join(
                left.cols[a["left_on"]], right.cols[a["right_on"]], mesh)
            n = data_axis_size(mesh)
            build_b = sum(int(v.size) * v.dtype.itemsize
                          for v in right.cols.values()) + right.capacity
            _annotate(ctx, dist="broadcast", coll="all_gather",
                      coll_bytes=coll_allgather_bytes(build_b, n))
            cols = _merge_join_cols(left, right, a["right_on"], idx)
            valid = left.valid & matched & right.valid[idx]
            return BoundedRel(cols, valid, None,
                              left.overflow | right.overflow)
    return _step_rel_join(args[0], args[1], node.attrs, ctx)


@_PALLAS.impl("rel_join_probe_pallas")
def _i_rel_join_probe(ctx, args, node):
    return _step_rel_join_probe(args[0], args[1], node.attrs, ctx,
                                interpret=ctx.interpret)


@REL_ENGINE.impl("bounded_join_col")
def _i_bounded_join(ctx, args, node):
    a = node.attrs
    mesh = getattr(ctx, "mesh", None)
    if a.get("dist") == "partitioned":
        left, right = as_bounded(args[0]), as_bounded(args[1])
        cap = int(a["capacity"])
        if _shardable(mesh, left.capacity, right.capacity, cap):
            # co-partition both sides on the key (one all-to-all of fixed
            # bucket_cap buckets), then join shard-locally.  Output rows
            # land in shard-major slot order: same match *set* as the
            # dense join, different slot order.
            bucket_cap = int(a.get("bucket_cap", 64))
            lidx, ridx, valid, count, ovf = sharded_partitioned_join(
                left.cols[a["left_on"]], left.valid,
                right.cols[a["right_on"]], right.valid,
                cap, mesh, bucket_cap)
            n = data_axis_size(mesh)
            # both sides route (n, bucket_cap) staged buckets of
            # (key, slot-index, validity) rows through one all_to_all
            staged = 2 * n * bucket_cap * (4 + 4 + 1)
            _annotate(ctx, dist="partitioned", coll="all_to_all",
                      coll_bytes=coll_all_to_all_bytes(staged, n),
                      bucket_cap=bucket_cap)
            # shuffle scratch counts toward the ledger high-water mark:
            # the staged buckets live only inside this executed program,
            # but their bytes are real device memory at peak
            default_ledger().note_transient(
                ("shuffle_buckets", node.id), staged * n,
                kind="shuffle_buckets")
            gathered = left.with_cols(
                {k: v[lidx] for k, v in left.cols.items()})
            cols = _merge_join_cols(gathered, right, a["right_on"], ridx)
            return BoundedRel(cols, valid, count,
                              ovf | left.overflow | right.overflow)
    return _step_bounded_join(args[0], args[1], node.attrs, ctx)


@REL_ENGINE.impl("rel_group_agg_col")
def _i_rel_group(ctx, args, node):
    a = node.attrs
    mesh = getattr(ctx, "mesh", None)
    rel = as_bounded(args[0])
    if a.get("dist") == "row" and _shardable(mesh, rel.capacity):
        # shard-local segment reduce + psum/pmax (cross-shard float sums
        # re-associate: allclose to the dense aggregate, not bitwise)
        key = rel.cols[a["key"]]
        g = int(a["num_groups"])
        _annotate(ctx, dist="row", coll="psum",
                  coll_bytes=coll_psum_bytes(
                      (len(a["aggs"]) + 1) * g * 4, data_axis_size(mesh)))
        cols = {a["key"]: jnp.arange(g, dtype=jnp.int32)}
        for out_name, fn, col in a["aggs"]:
            vals = None if fn == "count" else rel.cols[col]
            r = sharded_group_agg(vals, key, g, rel.valid, fn, mesh)
            if fn == "max":
                r, _valid = r
            cols[out_name] = r
        count = sharded_group_agg(None, key, g, rel.valid, "count", mesh)
        return BoundedRel(cols, count > 0, None, rel.overflow)
    return _step_rel_group_agg(args[0], node.attrs, ctx)


@REL_ENGINE.impl("compact_prefix_col")
def _i_compact(ctx, args, node):
    return _step_compact(args[0], node.attrs, ctx)


@_PALLAS.impl("compact_prefix_pallas")
def _i_compact_pallas(ctx, args, node):
    return _step_compact_pallas(args[0], node.attrs, ctx,
                                interpret=ctx.interpret)


@REL_ENGINE.impl("rel_fused_col")
def _i_rel_fused(ctx, args, node):
    return _run_chain(args, node.attrs["chain"], ctx)


@_PALLAS.impl("rel_fused_agg_pallas")
def _i_rel_fused_agg(ctx, args, node):
    """Fused chain whose terminal group-by runs the masked segment-
    aggregate Pallas kernel (sum/count/mean; gated by the pattern set)."""
    chain = node.attrs["chain"]
    rel = as_bounded(_run_chain(args, chain, ctx, stop_before_last=True))
    attrs = chain[-1][1]
    key = rel.cols[attrs["key"]]
    g = int(attrs["num_groups"])
    mw = rel.valid.astype(jnp.float32)
    cols = {attrs["key"]: jnp.arange(g, dtype=jnp.int32)}
    count = None
    for out_name, fn, col in attrs["aggs"]:
        vals = mw if fn == "count" else rel.cols[col]
        s, c = masked_segment_agg_pallas(vals, key, mw, num_groups=g,
                                         interpret=ctx.interpret)
        count = c
        cols[out_name] = (c if fn == "count"
                          else s if fn == "sum"
                          else s / jnp.maximum(c, 1.0))
    if count is None:
        count, _ = masked_segment_agg_pallas(mw, key, mw, num_groups=g,
                                             interpret=ctx.interpret)
    return BoundedRel(cols, count > 0, None, rel.overflow)


@REL_ENGINE.impl("col_tensor_rel")
def _i_col_tensor(ctx, args, node):
    rel = as_bounded(args[0])
    v = rel.cols[node.attrs["col"]].astype(node.attrs.get("dtype", "float32"))
    return jnp.where(rel.valid, v, jnp.zeros_like(v))


@REL_ENGINE.impl("sel_mask_rel")
def _i_sel_mask(ctx, args, node):
    """Selection-mask export: scatter the relation's validity over an
    entity domain (``mask[v] = any selected row with col == v``) — the
    boolean predicate pushdown hands across the engine boundary."""
    rel = as_bounded(args[0])
    col = rel.cols[node.attrs["col"]]
    size = int(node.attrs["size"])
    m = rel.valid & (col >= 0) & (col < size)
    idx = jnp.clip(col, 0, size - 1)
    out = jnp.zeros((size,), jnp.bool_).at[idx].max(m)
    if ctx.aux.get("count_sink") is not None:
        _record_count(ctx, sel_mask_site(node.attrs),
                      jnp.sum(out.astype(jnp.int32)), size)
    return out


# --------------------------------------------------------------------------
# graph engine (CSR fallback) + Pallas frontier kernels
# --------------------------------------------------------------------------


@GRAPH_ENGINE.impl("graph_expand_csr")
def _i_expand_csr(ctx, args, node):
    g, mesh = args[0], getattr(ctx, "mesh", None)
    if (node.attrs.get("dist") == "block" and "blk_src" in g
            and _shardable(mesh, g["indptr"].shape[0] - 1,
                           g["blk_src"].shape[0])):
        hops = int(node.attrs.get("hops", 1))
        nodes_b = (g["indptr"].shape[0] - 1) * 4
        _annotate(ctx, dist="block", coll="all_gather",
                  coll_bytes=hops * coll_allgather_bytes(
                      nodes_b, data_axis_size(mesh)))
        return sharded_expand(g, args[1], hops, mesh)
    return expand_frontier(args[0], args[1],
                           hops=int(node.attrs.get("hops", 1)))


@GRAPH_ENGINE.impl("graph_expand_skip")
def _i_expand_skip(ctx, args, node):
    return expand_frontier_blockskip(args[0], args[1],
                                     hops=int(node.attrs.get("hops", 1)))


@_PALLAS.impl("graph_expand_pallas")
def _i_expand_pallas(ctx, args, node):
    return expand_frontier(args[0], args[1],
                           hops=int(node.attrs.get("hops", 1)),
                           use_pallas=True, interpret=ctx.interpret)


@GRAPH_ENGINE.impl("graph_pagerank_csr")
def _i_pagerank_csr(ctx, args, node):
    g, mesh = args[0], getattr(ctx, "mesh", None)
    if (node.attrs.get("dist") == "block" and "blk_src" in g
            and _shardable(mesh, g["indptr"].shape[0] - 1,
                           g["blk_src"].shape[0])):
        iters = int(node.attrs.get("iters", 10))
        nodes_b = (g["indptr"].shape[0] - 1) * 4
        _annotate(ctx, dist="block", coll="all_gather",
                  coll_bytes=iters * coll_allgather_bytes(
                      nodes_b, data_axis_size(mesh)))
        return sharded_pagerank(
            g, iters, float(node.attrs.get("damping", 0.85)),
            args[1] if len(args) > 1 else None, mesh)
    return pagerank(args[0], iters=int(node.attrs.get("iters", 10)),
                    damping=float(node.attrs.get("damping", 0.85)),
                    personalization=args[1] if len(args) > 1 else None)


@GRAPH_ENGINE.impl("graph_pagerank_skip")
def _i_pagerank_skip(ctx, args, node):
    """Personalization-sparsity pushdown: iteration 0's SpMV block-skips on
    the pushed mask's support; bitwise-identical to the dense iteration."""
    return pagerank(args[0], iters=int(node.attrs.get("iters", 10)),
                    damping=float(node.attrs.get("damping", 0.85)),
                    personalization=args[1] if len(args) > 1 else None,
                    skip_first=True)


@_PALLAS.impl("graph_pagerank_pallas")
def _i_pagerank_pallas(ctx, args, node):
    return pagerank(args[0], iters=int(node.attrs.get("iters", 10)),
                    damping=float(node.attrs.get("damping", 0.85)),
                    personalization=args[1] if len(args) > 1 else None,
                    use_pallas=True, interpret=ctx.interpret)


@GRAPH_ENGINE.impl("graph_tricount_csr")
def _i_tricount(ctx, args, node):
    return triangle_count(args[0])


# --------------------------------------------------------------------------
# text engine
# --------------------------------------------------------------------------


def _topk_rel(ids, scores, valid):
    """Top-k results are a BoundedRel by construction: the valid slots form
    a prefix, so the traced count is the true result size (what the old
    ``valid=False`` overflow-slot convention encoded implicitly)."""
    return BoundedRel({"doc": ids, "score": scores}, valid)


@TEXT_ENGINE.impl("text_topk_inv")
def _i_text_topk(ctx, args, node):
    k = int(node.attrs["k"])
    if len(args) == 3:
        # pushed candidate-doc mask, dense realization: score the whole
        # corpus, then mask + top-k (the bitwise reference the skipping
        # candidates must reproduce)
        return _topk_rel(*tfidf_topk_masked(args[0], args[1], args[2], k))
    c, mesh = args[0], getattr(ctx, "mesh", None)
    if (node.attrs.get("dist") == "doc" and "blk_doc_local" in c
            and _shardable(mesh, c["doc_len"].shape[0],
                           c["blk_doc_local"].shape[0])):
        # shard-local score + local top-k, then a fixed-capacity candidate
        # merge (bitwise = the dense top-k, incl. tie-breaking)
        n = data_axis_size(mesh)
        _annotate(ctx, dist="doc", coll="all_gather",
                  coll_bytes=coll_allgather_bytes(n * k * 8, n))
        return _topk_rel(*sharded_tfidf_topk(c, args[1], k, mesh))
    return _topk_rel(*tfidf_topk(args[0], args[1], k))


@TEXT_ENGINE.impl("text_topk_skip_inv")
def _i_text_topk_skip(ctx, args, node):
    return _topk_rel(*tfidf_topk_blockskip(args[0], args[1], args[2],
                                           int(node.attrs["k"])))


@_PALLAS.impl("text_topk_masked_pallas")
def _i_text_topk_pallas(ctx, args, node):
    """Masked TF-IDF scoring through the one-hot-matmul superkernel: the
    per-posting gathers run in XLA, the masked fused reduce in Pallas."""
    corpus, query, doc_mask = args
    w = query.astype(jnp.float32) * corpus["idf"]
    doc_ids = corpus["doc_ids"]
    scores = masked_tfidf_pallas(
        doc_ids, w[corpus["term_ids"]], corpus["tf"],
        corpus["doc_len"][doc_ids], doc_mask[doc_ids],
        n_docs=int(corpus["doc_len"].shape[0]), interpret=ctx.interpret)
    return _topk_rel(*masked_topk(scores, doc_mask, int(node.attrs["k"])))


@TEXT_ENGINE.impl("text_scores_inv")
def _i_text_scores(ctx, args, node):
    return tfidf_scores(args[0], args[1])


@_XLA.impl("masked_topk_xla")
def _i_masked_topk(ctx, args, node):
    return _topk_rel(*masked_topk(args[0], args[1],
                                  int(node.attrs["k"])))


# --------------------------------------------------------------------------
# cross-engine transfer
# --------------------------------------------------------------------------


@_XLA.impl("xfer_pin")
def _i_xfer_pin(ctx, args, node):
    # AWESOME's in-memory placement: the value stays device-resident; the
    # receiving engine reads it in place (a no-op at run time — the win is
    # exactly that nothing happens here)
    return args[0]


def _host_roundtrip(v):
    return jax.tree.map(lambda a: np.array(a, copy=True), v)


@_XLA.impl("xfer_local", "xfer_repartition")
def _i_xfer_local(ctx, args, node):
    # layout-compatible handoff (and the repartition placement: the actual
    # all-to-all executes *fused inside* the partitioned join's shard_map —
    # this node is where the planner prices that traffic)
    return args[0]


@_XLA.impl("xfer_replicate")
def _i_xfer_replicate(ctx, args, node):
    # all-gather a data-partitioned value for dense consumers: realized as
    # a replicated sharding constraint on the mesh (GSPMD inserts the
    # gather); identity off-mesh
    mesh = getattr(ctx, "mesh", None)
    if mesh is None:
        return args[0]
    rep = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())

    def pin(a):
        try:
            return jax.lax.with_sharding_constraint(a, rep)
        except Exception:
            return a

    return jax.tree.map(pin, args[0])


@_XLA.impl("xfer_spill")
def _i_xfer_spill(ctx, args, node):
    # per-op materialization: the value round-trips device -> host -> device
    # (what a naive federated mediator does between every engine call).
    # pure_callback keeps this expressible under jit while still forcing
    # the host copy at every execution.  BoundedRel is a registered pytree,
    # so relations spill column-wise like any other plan value.
    x = args[0]
    shapes = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(jnp.shape(a), jnp.result_type(a)), x)
    return jax.pure_callback(_host_roundtrip, shapes, x)
