"""Executor implementations for the tri-store physical operators.

Each store engine owns its impl table (``engines.py``); importing this
module registers the relational / graph / text implementations plus the two
cross-engine transfer realizations.  Store values travel through the plan
as pytrees of JAX arrays (tables as column dicts with a ``_mask`` selection
vector, graphs/corpora as their CSR/COO payload dicts), so a whole
tri-model plan stays jittable end to end.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..core.engines import get_engine
from .base import GRAPH_ENGINE, REL_ENGINE, TEXT_ENGINE
from .column_store import MASK, filter_mask, group_agg, hash_join, table_mask
from .graph_store import expand_frontier, pagerank, triangle_count
from .text_store import tfidf_topk

_XLA = get_engine("xla")
_PALLAS = get_engine("pallas")


# --------------------------------------------------------------------------
# relational engine
# --------------------------------------------------------------------------


@REL_ENGINE.impl("rel_scan_col")
def _i_rel_scan(ctx, args, node):
    tbl = dict(args[0])
    mask = table_mask(tbl)
    cols = node.attrs.get("cols")
    if cols:
        tbl = {c: tbl[c] for c in cols}
    tbl.pop(MASK, None)
    tbl[MASK] = mask
    return tbl


@REL_ENGINE.impl("rel_filter_col")
def _i_rel_filter(ctx, args, node):
    tbl = dict(args[0])
    m = filter_mask(tbl[node.attrs["col"]], node.attrs["cmp"],
                    node.attrs["value"])
    tbl[MASK] = table_mask(tbl) & m
    return tbl


@REL_ENGINE.impl("rel_hash_join")
def _i_rel_join(ctx, args, node):
    left, right = dict(args[0]), dict(args[1])
    lo, ro = node.attrs["left_on"], node.attrs["right_on"]
    idx, matched = hash_join(left[lo], right[ro])
    lmask = table_mask(left)
    rmask = table_mask(right)[idx]
    out = {k: v for k, v in left.items() if k != MASK}
    for k, v in right.items():
        if k in (ro, MASK) or k in out:
            continue
        out[k] = v[idx]
    out[MASK] = lmask & matched & rmask
    return out


@REL_ENGINE.impl("rel_group_agg_col")
def _i_rel_group(ctx, args, node):
    tbl = args[0]
    key = tbl[node.attrs["key"]]
    g = int(node.attrs["num_groups"])
    mask = table_mask(tbl)
    out = {node.attrs["key"]: jnp.arange(g, dtype=jnp.int32)}
    for out_name, fn, col in node.attrs["aggs"]:
        vals = None if fn == "count" else tbl[col]
        out[out_name] = group_agg(vals, key, g, mask, fn)
    count = group_agg(None, key, g, mask, "count")
    out[MASK] = count > 0
    return out


@REL_ENGINE.impl("col_tensor_rel")
def _i_col_tensor(ctx, args, node):
    tbl = args[0]
    v = tbl[node.attrs["col"]].astype(node.attrs.get("dtype", "float32"))
    return jnp.where(table_mask(tbl), v, jnp.zeros_like(v))


# --------------------------------------------------------------------------
# graph engine (CSR fallback) + Pallas frontier kernels
# --------------------------------------------------------------------------


@GRAPH_ENGINE.impl("graph_expand_csr")
def _i_expand_csr(ctx, args, node):
    return expand_frontier(args[0], args[1],
                           hops=int(node.attrs.get("hops", 1)))


@_PALLAS.impl("graph_expand_pallas")
def _i_expand_pallas(ctx, args, node):
    return expand_frontier(args[0], args[1],
                           hops=int(node.attrs.get("hops", 1)),
                           use_pallas=True, interpret=ctx.interpret)


@GRAPH_ENGINE.impl("graph_pagerank_csr")
def _i_pagerank_csr(ctx, args, node):
    return pagerank(args[0], iters=int(node.attrs.get("iters", 10)),
                    damping=float(node.attrs.get("damping", 0.85)),
                    personalization=args[1] if len(args) > 1 else None)


@_PALLAS.impl("graph_pagerank_pallas")
def _i_pagerank_pallas(ctx, args, node):
    return pagerank(args[0], iters=int(node.attrs.get("iters", 10)),
                    damping=float(node.attrs.get("damping", 0.85)),
                    personalization=args[1] if len(args) > 1 else None,
                    use_pallas=True, interpret=ctx.interpret)


@GRAPH_ENGINE.impl("graph_tricount_csr")
def _i_tricount(ctx, args, node):
    return triangle_count(args[0])


# --------------------------------------------------------------------------
# text engine
# --------------------------------------------------------------------------


@TEXT_ENGINE.impl("text_topk_inv")
def _i_text_topk(ctx, args, node):
    ids, scores = tfidf_topk(args[0], args[1], int(node.attrs["k"]))
    return {"doc": ids, "score": scores,
            MASK: jnp.ones(ids.shape, jnp.bool_)}


# --------------------------------------------------------------------------
# cross-engine transfer
# --------------------------------------------------------------------------


@_XLA.impl("xfer_pin")
def _i_xfer_pin(ctx, args, node):
    # AWESOME's in-memory placement: the value stays device-resident; the
    # receiving engine reads it in place (a no-op at run time — the win is
    # exactly that nothing happens here)
    return args[0]


def _host_roundtrip(v):
    return jax.tree.map(lambda a: np.array(a, copy=True), v)


@_XLA.impl("xfer_spill")
def _i_xfer_spill(ctx, args, node):
    # per-op materialization: the value round-trips device -> host -> device
    # (what a naive federated mediator does between every engine call).
    # pure_callback keeps this expressible under jit while still forcing
    # the host copy at every execution.
    x = args[0]
    shapes = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(jnp.shape(a), jnp.result_type(a)), x)
    return jax.pure_callback(_host_roundtrip, shapes, x)
