"""Pallas TPU kernel for the graph store's frontier ops.

The core primitive of every CSR frontier op (k-hop expansion, PageRank
iteration) is the scatter-add ``y[dst[e]] += val[e]``.  A TPU has no fast
random scatter, so the kernel reformulates the reduction as a **one-hot
matmul**: for an edge block and a node block, ``(1, E_blk) @ (E_blk, N_blk)``
where the right operand is the mask ``dst[e] == node_id[n]`` — an
MXU-shaped contraction with no gathers or scatters inside the kernel.  The
node-block accumulator lives in VMEM scratch across the (sequential,
innermost) edge-block grid axis, so each output tile is written to HBM
exactly once — the bytes advantage the cost model credits the Pallas
candidate with.

The value gather ``x[src[e]] * w[e]`` happens *outside* the kernel (XLA
gathers are fine); the kernel owns the scatter side only.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scatter_add_kernel(dst_ref, val_ref, o_ref, acc_ref, *, block_n):
    eb = pl.program_id(1)

    @pl.when(eb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    node_base = pl.program_id(0) * block_n
    node_ids = jax.lax.broadcasted_iota(jnp.int32, (1, block_n), 1) + node_base
    dst = dst_ref[...]                       # (1, E_blk) int32
    val = val_ref[...]                       # (1, E_blk) float32
    onehot = (dst[0][:, None] == node_ids[0][None, :]).astype(jnp.float32)
    acc_ref[...] += jnp.dot(val, onehot, preferred_element_type=jnp.float32)

    @pl.when(eb == pl.num_programs(1) - 1)
    def _fin():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("num_nodes", "block_e", "block_n",
                                    "interpret"))
def scatter_add_pallas(vals, dst, *, num_nodes: int, block_e: int = 512,
                       block_n: int = 256, interpret: bool = True):
    """``y[n] = sum over e with dst[e]==n of vals[e]`` for ``n < num_nodes``.

    Edge padding uses ``dst = -1`` (matches no node); node padding is
    sliced off the result.
    """
    e = vals.shape[0]
    if e == 0:  # zero-edge graph: nothing to scatter (shape is static)
        return jnp.zeros((num_nodes,), jnp.float32)
    be = min(block_e, max(8, e))
    bn = min(block_n, max(128, num_nodes))
    e_pad = (-e) % be
    n_pad = (-num_nodes) % bn
    vals = jnp.pad(vals.astype(jnp.float32), (0, e_pad))[None, :]
    dst = jnp.pad(dst.astype(jnp.int32), (0, e_pad),
                  constant_values=-1)[None, :]
    n_tot = num_nodes + n_pad

    grid = (n_tot // bn, (e + e_pad) // be)
    out = pl.pallas_call(
        functools.partial(_scatter_add_kernel, block_n=bn),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, be), lambda nb, ebk: (0, ebk)),
            pl.BlockSpec((1, be), lambda nb, ebk: (0, ebk)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda nb, ebk: (0, nb)),
        out_shape=jax.ShapeDtypeStruct((1, n_tot), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, bn), jnp.float32)],
        interpret=interpret,
    )(dst, vals)
    return out[0, :num_nodes]
