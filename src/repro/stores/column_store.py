"""Columnar relational store: struct-of-JAX-arrays tables.

A table value at run time is a :class:`~repro.stores.bounded.BoundedRel` —
one ``(capacity,)`` array per column plus a ``valid`` vector and a traced
row ``count`` — so every relational kernel below is static-shaped and
jittable (the columnar analogue of a late-materialized selection vector,
with the cardinality carried alongside instead of hidden in a mask column).

Kernels:

  * :func:`filter_mask`          — predicate over one column;
  * :func:`hash_join`            — equi-join probe against a *unique-key*
    build side (sort + binary-search, the static-shape realization of a
    hash join's build/probe phases);
  * :func:`hash_join_nonunique`  — equi-join against a **non-unique** build
    side: every key match emits an output slot into a capacity-bounded,
    validity-prefixed result (overflow flagged, never silent);
  * :func:`group_agg`            — segment-reduce per group id (sum /
    count / mean / max), mask-weighted.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.ir import TableT, ValidationError
from ..core.ledger import register_store_payload
from .bounded import MASK, BoundedRel

_CMP = {
    "eq": lambda a, b: a == b,
    "ne": lambda a, b: a != b,
    "lt": lambda a, b: a < b,
    "le": lambda a, b: a <= b,
    "gt": lambda a, b: a > b,
    "ge": lambda a, b: a >= b,
}


class ColumnStore:
    """Host-side container for one table: named columns of equal length.

    Columns are canonicalized to 32-bit on ingest (the device
    representation: JAX without x64 silently degrades 64-bit arrays, so the
    store does the narrowing *explicitly* and refuses integer columns whose
    values would wrap rather than corrupting keys silently).

    ``capacity`` (>= the ingested row count) preallocates headroom for
    :meth:`append`: appends within capacity keep the device shape — and
    therefore every compiled plan's input signature — fixed, so incremental
    ingest does not force recompilation for shape reasons.  Every append
    bumps the monotonic ``version``; the planner folds bound-store versions
    into the plan-cache key, so plans priced against stale row statistics
    are invalidated rather than reused.
    """

    def __init__(self, columns: Dict[str, np.ndarray],
                 capacity: Optional[int] = None, shards: int = 1):
        if not columns:
            raise ValidationError("ColumnStore needs >= 1 column")
        lens = {k: len(v) for k, v in columns.items()}
        if len(set(lens.values())) != 1:
            raise ValidationError(f"ragged columns: {lens}")
        self._cols = {k: self._canon_col(k, np.asarray(v))
                      for k, v in columns.items()}
        self.rows = next(iter(lens.values()))
        self.capacity = self.rows if capacity is None else int(capacity)
        if self.capacity < self.rows:
            raise ValidationError(
                f"capacity {self.capacity} < ingested rows {self.rows}")
        # row-range sharding over the mesh's data axis: capacity rounds up
        # to a shard multiple (the pad rows are valid=False, so every kernel
        # already ignores them) and the type advertises partitioning="row"
        self.shards = int(shards)
        if self.shards < 1:
            raise ValidationError(f"shards {self.shards} < 1")
        if self.shards > 1:
            self.capacity += (-self.capacity) % self.shards
        self.version = 0

    def with_shards(self, shards: int) -> "ColumnStore":
        """This table re-declared as row-partitioned over ``shards`` mesh
        slices (shares the ingested column data)."""
        out = ColumnStore(self._cols, capacity=self.capacity, shards=shards)
        out.rows = self.rows
        out.version = self.version
        return out

    @staticmethod
    def _canon_col(name: str, col: np.ndarray) -> np.ndarray:
        if col.dtype in (np.int64, np.uint64, np.uint32):
            info = np.iinfo(np.int32)
            if col.size and (col.min() < info.min or col.max() > info.max):
                raise ValidationError(
                    f"column {name!r}: int values exceed int32 range; "
                    f"re-key before ingest (device tables are 32-bit)")
            return col.astype(np.int32)
        if col.dtype == np.float64:
            return col.astype(np.float32)
        return col

    @property
    def type(self) -> TableT:
        # expected_count is only carried when headroom exists: a full table
        # is the fully-valid default (None), keeping base-table types stable
        exp = None if self.rows == self.capacity else self.rows
        return TableT(tuple((k, str(v.dtype)) for k, v in self._cols.items()),
                      self.capacity, exp,
                      "row" if self.shards > 1 else None)

    def payload(self) -> BoundedRel:
        cols = {}
        for k, v in self._cols.items():
            pad = self.capacity - self.rows
            cols[k] = jnp.asarray(np.pad(v, (0, pad)) if pad else v)
        valid = jnp.arange(self.capacity, dtype=jnp.int32) < self.rows
        rel = BoundedRel(cols, valid, jnp.int32(self.rows))
        register_store_payload(self, rel, "column_store")
        return rel

    def column(self, name: str) -> np.ndarray:
        return self._cols[name][:self.rows]

    def append(self, columns: Dict[str, np.ndarray]) -> "ColumnStore":
        """Append rows (same schema).  Appends beyond ``capacity`` grow it
        to the new row count (a shape — and therefore plan-type — change);
        either way the store ``version`` bumps, invalidating cached plans
        planned against the previous contents."""
        if set(columns) != set(self._cols):
            raise ValidationError(
                f"append schema mismatch: {sorted(columns)} vs "
                f"{sorted(self._cols)}")
        lens = {k: len(v) for k, v in columns.items()}
        if len(set(lens.values())) != 1:
            raise ValidationError(f"ragged append: {lens}")
        new = {k: self._canon_col(k, np.asarray(v))
               for k, v in columns.items()}
        for k, v in new.items():
            if v.dtype != self._cols[k].dtype:
                raise ValidationError(
                    f"append column {k!r}: dtype {v.dtype} != "
                    f"{self._cols[k].dtype}")
            self._cols[k] = np.concatenate([self._cols[k], v])
        self.rows += next(iter(lens.values()))
        self.capacity = max(self.capacity, self.rows)
        if self.shards > 1:
            self.capacity += (-self.capacity) % self.shards
        self.version += 1
        return self


# --------------------------------------------------------------------------
# relational kernels (pure functions over column arrays)
# --------------------------------------------------------------------------


def table_mask(tbl) -> jnp.ndarray:
    if isinstance(tbl, BoundedRel):
        return tbl.valid
    if MASK in tbl:
        return tbl[MASK]
    any_col = next(v for k, v in tbl.items() if k != MASK)
    return jnp.ones(any_col.shape[:1], jnp.bool_)


def filter_mask(col: jnp.ndarray, cmp: str, value) -> jnp.ndarray:
    if cmp not in _CMP:
        raise ValidationError(f"filter: unknown cmp {cmp!r}")
    return _CMP[cmp](col, value)


def hash_join(lkeys: jnp.ndarray, rkeys: jnp.ndarray):
    """Equi-join probe: for every left key, the index of the matching right
    row and a match flag.  The build side must have unique keys (the
    dimension-table convention); for duplicate build keys use
    :func:`hash_join_nonunique`, whose capacity-bounded output makes the
    dynamic result size expressible on a static-shape engine.

    Returns ``(idx, matched)`` with ``idx.shape == lkeys.shape``.
    """
    if rkeys.shape[0] == 0:   # empty build side: every probe row unmatched
        return (jnp.zeros(lkeys.shape, jnp.int32),
                jnp.zeros(lkeys.shape, jnp.bool_))
    order = jnp.argsort(rkeys)
    sorted_r = rkeys[order]
    pos = jnp.searchsorted(sorted_r, lkeys)
    pos = jnp.clip(pos, 0, rkeys.shape[0] - 1)
    idx = order[pos]
    matched = sorted_r[pos] == lkeys
    return idx, matched


def hash_join_nonunique(lkeys, lmask, rkeys, rmask, capacity: int):
    """Equi-join with a **non-unique build side**, capacity-bounded.

    Every (valid probe row, valid build row) key match claims one output
    slot, ordered by probe row and — within one probe row — by the build
    side's (key-stable) sorted order.  The output is a validity *prefix*:
    slots ``[0, count)`` hold matches, the rest are placeholders.  When the
    true match total exceeds ``capacity`` the excess is dropped and
    ``overflow`` is returned True — bounded, flagged, never silent.

    Invalid build rows are excluded via a rank-select over the sorted
    validity prefix sum (not a key sentinel: device keys are int32 end to
    end, so there is no spare key space to hide a sentinel in).

    Returns ``(lidx, ridx, valid, count, overflow)``, each of the first
    three shaped ``(capacity,)``.
    """
    cap = int(capacity)
    if cap >= 1 << 23:
        raise ValidationError(
            f"bounded_join: capacity {cap} >= 2^23 (the slot-owner search "
            f"needs exact float32 prefix sums in the emitted region)")
    nl, nr = int(lkeys.shape[0]), int(rkeys.shape[0])
    j = jnp.arange(cap, dtype=jnp.int32)
    if nl == 0 or nr == 0:
        z = jnp.zeros((cap,), jnp.int32)
        return (z, z, jnp.zeros((cap,), jnp.bool_), jnp.int32(0),
                jnp.asarray(False))
    order = jnp.argsort(rkeys, stable=True)
    sk = rkeys[order]
    valids = rmask[order].astype(jnp.int32)
    cum = jnp.cumsum(valids)                    # inclusive valid-row counts
    lo = jnp.searchsorted(sk, lkeys, side="left")
    hi = jnp.searchsorted(sk, lkeys, side="right")
    before = jnp.where(lo > 0, cum[jnp.maximum(lo - 1, 0)], 0)
    upto = jnp.where(hi > 0, cum[jnp.maximum(hi - 1, 0)], 0)
    cnt = jnp.where(lmask, upto - before, 0).astype(jnp.int32)
    # clamp per-probe counts at cap+1 (slot ownership for every emitted
    # slot j < cap is invariant: a row's clamped range still covers any j
    # it truly owns, since j - start < cap + 1, and the overflow predicate
    # total > cap is preserved), then accumulate the per-probe ends in
    # float32: a skewed cross-join's true match total — and even the
    # clamped nl*(cap+1) bound — can exceed 2^31 and wrap an int32 cumsum
    # negative.  Float32 prefix sums of non-negative terms stay monotone,
    # and every value that decides an emitted slot is <= 2*cap + 1 < 2^24,
    # hence exact (the capacity guard above enforces this).
    cnt = jnp.minimum(cnt, cap + 1)
    ends = jnp.cumsum(cnt.astype(jnp.float32))  # inclusive per-probe ends
    total = ends[-1]
    # owner probe row of output slot j: first row whose end exceeds j
    i = jnp.clip(jnp.searchsorted(ends, j.astype(jnp.float32),
                                  side="right"), 0, nl - 1)
    rank = (j - (ends[i] - cnt[i])).astype(jnp.int32)
    # rank-th *valid* sorted build row at/after lo[i]: the first sorted
    # position whose inclusive valid count reaches before[i] + rank + 1
    p = jnp.searchsorted(cum, before[i] + rank + 1, side="left")
    rpos = order[jnp.clip(p, 0, nr - 1)]
    count = jnp.minimum(total, float(cap)).astype(jnp.int32)
    valid = j < count
    overflow = total > cap
    return (i.astype(jnp.int32), rpos.astype(jnp.int32), valid, count,
            overflow)


def group_agg(values: Optional[jnp.ndarray], keys: jnp.ndarray,
              num_groups: int, mask: jnp.ndarray, fn: str):
    """Mask-weighted segment aggregate of ``values`` per group id.

    ``fn="max"`` returns a ``(values, valid)`` pair: ``valid[g]`` is False
    for groups with no unmasked rows (whose value slot is filled with 0.0)
    — a group whose true max *is* 0.0 stays distinguishable from an empty
    one.  The other aggregates return the value array alone (an empty
    group's sum/count of 0.0 is the correct aggregate, not a sentinel).
    At the relation level both cases surface uniformly: ``rel_group_agg``
    emits a BoundedRel whose row validity is exactly the occupied-group
    mask, so "no such group" is the relation's own validity story rather
    than a per-aggregate convention.
    """
    w = mask.astype(jnp.float32)
    if fn == "count":
        return jax.ops.segment_sum(w, keys, num_segments=num_groups)
    v = values.astype(jnp.float32)
    if fn == "sum":
        return jax.ops.segment_sum(v * w, keys, num_segments=num_groups)
    if fn == "mean":
        s = jax.ops.segment_sum(v * w, keys, num_segments=num_groups)
        c = jax.ops.segment_sum(w, keys, num_segments=num_groups)
        return s / jnp.maximum(c, 1.0)
    if fn == "max":
        neg = jnp.where(mask, v, -jnp.inf)
        m = jax.ops.segment_max(neg, keys, num_segments=num_groups)
        valid = jnp.isfinite(m)
        return jnp.where(valid, m, 0.0), valid
    raise ValidationError(f"group_agg: unknown fn {fn!r}")
