"""Columnar relational store: struct-of-JAX-arrays tables.

A table value at run time is a dict ``{col_name: (rows,) array, ...,
"_mask": (rows,) bool}`` — the boolean selection vector realizes filters
without changing the physical row count, so every relational kernel below
is static-shaped and jittable (the columnar analogue of a late-materialized
selection vector).

Kernels:

  * :func:`filter_mask`     — predicate over one column, narrows the mask;
  * :func:`hash_join`       — equi-join against a unique-key build side
    (sort + binary-search probe, the static-shape realization of a hash
    join's build/probe phases);
  * :func:`group_agg`       — segment-reduce per group id (sum / count /
    mean / max), mask-weighted.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.ir import TableT, ValidationError

MASK = "_mask"

_CMP = {
    "eq": lambda a, b: a == b,
    "ne": lambda a, b: a != b,
    "lt": lambda a, b: a < b,
    "le": lambda a, b: a <= b,
    "gt": lambda a, b: a > b,
    "ge": lambda a, b: a >= b,
}


class ColumnStore:
    """Host-side container for one table: named columns of equal length.

    Columns are canonicalized to 32-bit on ingest (the device
    representation: JAX without x64 silently degrades 64-bit arrays, so the
    store does the narrowing *explicitly* and refuses integer columns whose
    values would wrap rather than corrupting keys silently).
    """

    def __init__(self, columns: Dict[str, np.ndarray]):
        if not columns:
            raise ValidationError("ColumnStore needs >= 1 column")
        lens = {k: len(v) for k, v in columns.items()}
        if len(set(lens.values())) != 1:
            raise ValidationError(f"ragged columns: {lens}")
        self._cols = {k: self._canon_col(k, np.asarray(v))
                      for k, v in columns.items()}
        self.rows = next(iter(lens.values()))

    @staticmethod
    def _canon_col(name: str, col: np.ndarray) -> np.ndarray:
        if col.dtype in (np.int64, np.uint64, np.uint32):
            info = np.iinfo(np.int32)
            if col.size and (col.min() < info.min or col.max() > info.max):
                raise ValidationError(
                    f"column {name!r}: int values exceed int32 range; "
                    f"re-key before ingest (device tables are 32-bit)")
            return col.astype(np.int32)
        if col.dtype == np.float64:
            return col.astype(np.float32)
        return col

    @property
    def type(self) -> TableT:
        return TableT(tuple((k, str(v.dtype)) for k, v in self._cols.items()),
                      self.rows)

    def payload(self) -> dict:
        out = {k: jnp.asarray(v) for k, v in self._cols.items()}
        out[MASK] = jnp.ones((self.rows,), jnp.bool_)
        return out

    def column(self, name: str) -> np.ndarray:
        return self._cols[name]


# --------------------------------------------------------------------------
# relational kernels (pure functions over column arrays)
# --------------------------------------------------------------------------


def table_mask(tbl: dict) -> jnp.ndarray:
    if MASK in tbl:
        return tbl[MASK]
    any_col = next(v for k, v in tbl.items() if k != MASK)
    return jnp.ones(any_col.shape[:1], jnp.bool_)


def filter_mask(col: jnp.ndarray, cmp: str, value) -> jnp.ndarray:
    if cmp not in _CMP:
        raise ValidationError(f"filter: unknown cmp {cmp!r}")
    return _CMP[cmp](col, value)


def hash_join(lkeys: jnp.ndarray, rkeys: jnp.ndarray):
    """Equi-join probe: for every left key, the index of the matching right
    row and a match flag.  The build side must have unique keys (the
    dimension-table convention); duplicate build keys would make the output
    size dynamic, which a static-shape engine cannot express.

    Returns ``(idx, matched)`` with ``idx.shape == lkeys.shape``.
    """
    if rkeys.shape[0] == 0:   # empty build side: every probe row unmatched
        return (jnp.zeros(lkeys.shape, jnp.int32),
                jnp.zeros(lkeys.shape, jnp.bool_))
    order = jnp.argsort(rkeys)
    sorted_r = rkeys[order]
    pos = jnp.searchsorted(sorted_r, lkeys)
    pos = jnp.clip(pos, 0, rkeys.shape[0] - 1)
    idx = order[pos]
    matched = sorted_r[pos] == lkeys
    return idx, matched


def group_agg(values: Optional[jnp.ndarray], keys: jnp.ndarray,
              num_groups: int, mask: jnp.ndarray, fn: str):
    """Mask-weighted segment aggregate of ``values`` per group id.

    ``fn="max"`` returns a ``(values, valid)`` pair: ``valid[g]`` is False
    for groups with no unmasked rows (whose value slot is filled with 0.0)
    — a group whose true max *is* 0.0 stays distinguishable from an empty
    one.  The other aggregates return the value array alone (an empty
    group's sum/count of 0.0 is the correct aggregate, not a sentinel).
    """
    w = mask.astype(jnp.float32)
    if fn == "count":
        return jax.ops.segment_sum(w, keys, num_segments=num_groups)
    v = values.astype(jnp.float32)
    if fn == "sum":
        return jax.ops.segment_sum(v * w, keys, num_segments=num_groups)
    if fn == "mean":
        s = jax.ops.segment_sum(v * w, keys, num_segments=num_groups)
        c = jax.ops.segment_sum(w, keys, num_segments=num_groups)
        return s / jnp.maximum(c, 1.0)
    if fn == "max":
        neg = jnp.where(mask, v, -jnp.inf)
        m = jax.ops.segment_max(neg, keys, num_segments=num_groups)
        valid = jnp.isfinite(m)
        return jnp.where(valid, m, 0.0), valid
    raise ValidationError(f"group_agg: unknown fn {fn!r}")
