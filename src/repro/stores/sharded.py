"""Sharded tri-store kernels: the stores partitioned over the mesh ``data``
axis.

Each store partitions along its natural record axis — ColumnStore by row
range, GraphStore by CSR dst-node blocks, TextStore by document range —
and every kernel here is an explicit :func:`shard_map` program whose only
cross-shard traffic is a named collective:

  * filter / count   — shard-local predicate, ``psum`` count (the feedback
    path: ``SelectivityFeedback`` keeps seeing *global* counts);
  * group-agg        — shard-local segment reduce + ``psum`` merge
    (float sums re-associate across shards: allclose, not bitwise);
  * broadcast join   — build side replicated, probe side row-partitioned;
    the probe-aligned output is **bitwise** equal to the dense join;
  * partitioned join — both sides hash-co-partitioned on the key via
    ``all_to_all`` into expected-count-bounded buckets (BoundedRel counts
    size the shuffle buffers), then joined shard-locally; slot order
    differs from the dense join (set-equal, not bitwise);
  * PageRank / k-hop — dst-block-partitioned SpMV with a per-iteration
    frontier ``all_gather``; the stable dst-block edge selection preserves
    per-destination contribution order, so results are **bitwise** equal;
  * top-k TF-IDF     — shard-local scoring + local top-k, then a fixed-
    capacity merge ordered by (score desc, doc asc) — exactly
    ``lax.top_k``'s lowest-index tie-breaking, so **bitwise** equal.

All inputs stay *logically global*: shard_map carves them by ``in_specs``,
so the same payloads run unsharded when no mesh (or a 1-wide data axis) is
present.  Global array lengths must divide the data-axis size — the stores
pad themselves when constructed with ``shards=``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map

from ..core.ir import ValidationError
from .column_store import hash_join, hash_join_nonunique

P = jax.sharding.PartitionSpec


def data_axis_size(mesh) -> int:
    if mesh is None or "data" not in getattr(mesh, "axis_names", ()):
        return 1
    return int(mesh.shape["data"])


def _shardable(mesh, *lengths) -> bool:
    n = data_axis_size(mesh)
    return n > 1 and all(int(ln) % n == 0 for ln in lengths)


# --------------------------------------------------------------------------
# collective-byte attribution (the runtime side of the cost model's wire-
# byte formulas: what each kernel's collectives actually move, per shard)
# --------------------------------------------------------------------------


def coll_allgather_bytes(nbytes: float, n: int) -> float:
    """Per-shard wire bytes of all-gathering an ``nbytes`` value that is
    partitioned over ``n`` shards: each receives the other (n-1)/n."""
    n = max(1, int(n))
    return float(nbytes) * (n - 1) / n


def coll_psum_bytes(nbytes: float, n: int) -> float:
    """Per-shard wire bytes of a tree all-reduce over an ``nbytes``-sized
    replicated result: log2(n) exchange rounds."""
    import math
    return float(nbytes) * math.log2(max(int(n), 2))


def coll_all_to_all_bytes(nbytes: float, n: int) -> float:
    """Per-shard wire bytes of an all-to-all over staged buckets totalling
    ``nbytes`` per shard: every shard keeps its own 1/n and ships the
    rest."""
    n = max(1, int(n))
    return float(nbytes) * (n - 1) / n


# --------------------------------------------------------------------------
# filter count (the psum feedback path)
# --------------------------------------------------------------------------


def sharded_count(valid, mesh):
    """Global valid-row count as a shard-local sum + ``psum``: the count a
    row-partitioned filter hands to ``SelectivityFeedback`` (identical to
    the dense count — integer addition is associative)."""

    def body(v):
        return jax.lax.psum(jnp.sum(v.astype(jnp.int32)), "data")

    return shard_map(body, mesh=mesh, in_specs=(P("data"),),
                     out_specs=P())(valid)


# --------------------------------------------------------------------------
# group aggregate (psum merge)
# --------------------------------------------------------------------------


def sharded_group_agg(values, keys, num_groups: int, mask, fn: str, mesh):
    """Mask-weighted segment aggregate over a row-partitioned relation:
    shard-local segment reduce, then ``psum`` (``pmax`` for ``max``) into
    the replicated (num_groups,) result.  Cross-shard float addition
    re-associates the dense sum — results are allclose, not bitwise."""
    ng = int(num_groups)

    def seg(v, k, m, red):
        w = m.astype(jnp.float32)
        if fn == "count":
            return red(jax.ops.segment_sum(w, k, num_segments=ng))
        vv = v.astype(jnp.float32)
        if fn == "sum":
            return red(jax.ops.segment_sum(vv * w, k, num_segments=ng))
        if fn == "mean":
            s = red(jax.ops.segment_sum(vv * w, k, num_segments=ng))
            c = red(jax.ops.segment_sum(w, k, num_segments=ng))
            return s / jnp.maximum(c, 1.0)
        if fn == "max":
            neg = jnp.where(m, vv, -jnp.inf)
            gm = jax.lax.pmax(
                jax.ops.segment_max(neg, k, num_segments=ng), "data")
            valid = jnp.isfinite(gm)
            return jnp.where(valid, gm, 0.0), valid
        raise ValidationError(f"sharded_group_agg: unknown fn {fn!r}")

    def body(v, k, m):
        return seg(v, k, m, lambda x: jax.lax.psum(x, "data"))

    out_specs = (P(), P()) if fn == "max" else P()
    vals = (jnp.zeros(keys.shape, jnp.float32) if values is None else values)
    return shard_map(body, mesh=mesh, in_specs=(P("data"),) * 3,
                     out_specs=out_specs)(vals, keys, mask)


# --------------------------------------------------------------------------
# joins
# --------------------------------------------------------------------------


def sharded_broadcast_join(lkeys, rkeys, mesh):
    """Unique-build-key equi-join with the build side replicated and the
    probe side row-partitioned: each shard probes its row block against the
    full build relation, so the probe-aligned ``(idx, matched)`` output is
    bitwise identical to the dense :func:`hash_join`."""

    def body(lk, rk):
        return hash_join(lk, rk)

    return shard_map(body, mesh=mesh, in_specs=(P("data"), P()),
                     out_specs=(P("data"), P("data")))(lkeys, rkeys)


def sharded_partitioned_join(lkeys, lmask, rkeys, rmask, capacity: int,
                             mesh, bucket_cap: int):
    """Non-unique-key equi-join with **both sides hash-co-partitioned on
    the key**: every shard routes its rows to ``owner = key % n_data`` via
    one ``all_to_all`` of fixed ``(n_data, bucket_cap)`` buckets, then runs
    the shard-local bounded join over what it received.

    ``bucket_cap`` bounds the shuffle buffer per (sender, owner) pair —
    the planner sizes it from the relation's *expected* count (BoundedRel
    cardinality), so a skewed key distribution overflows visibly (rows
    dropped, ``overflow=True``) instead of allocating for the worst case.

    Returns ``(lidx, ridx, valid, count, overflow)`` like
    :func:`hash_join_nonunique`, with ``lidx``/``ridx`` indexing the
    *global* row domain; output slots land in shard-major order, so the
    result is set-equal (not slot-identical) to the dense join.
    ``capacity`` must divide the data-axis size.
    """
    n = data_axis_size(mesh)
    cap = int(capacity)
    if cap % n:
        raise ValidationError(
            f"sharded_partitioned_join: capacity {cap} must divide "
            f"the data axis ({n})")
    cap_l = cap // n
    bcap = max(1, int(bucket_cap))

    def route(keys, mask, rows_l):
        """Scatter this shard's rows into (n, bcap) owner buckets."""
        gid0 = jax.lax.axis_index("data") * rows_l
        gids = gid0 + jnp.arange(rows_l, dtype=jnp.int32)
        owner = jnp.where(mask, keys % n, n)           # invalid -> trash
        order = jnp.argsort(owner, stable=True)
        so, sk, sg = owner[order], keys[order], gids[order]
        start = jnp.searchsorted(so, jnp.arange(n + 1, dtype=so.dtype))
        rank = jnp.arange(rows_l, dtype=jnp.int32) - start[
            jnp.clip(so, 0, n)].astype(jnp.int32)
        ok = (so < n) & (rank < bcap)
        slot = jnp.where(ok, so * bcap + rank, n * bcap)   # OOB -> dropped
        keys_b = jnp.zeros((n * bcap,), keys.dtype).at[slot].set(
            sk, mode="drop")
        gids_b = jnp.zeros((n * bcap,), jnp.int32).at[slot].set(
            sg, mode="drop")
        mask_b = jnp.zeros((n * bcap,), jnp.bool_).at[slot].set(
            ok, mode="drop")
        dropped = jnp.sum((so < n) & ~ok)
        return keys_b.reshape(n, bcap), gids_b.reshape(n, bcap), \
            mask_b.reshape(n, bcap), dropped

    def exchange(x):
        return jax.lax.all_to_all(x, "data", split_axis=0,
                                  concat_axis=0, tiled=False)

    def body(lk, lm, rk, rm):
        lkb, lgb, lmb, ldrop = route(lk, lm, lk.shape[0])
        rkb, rgb, rmb, rdrop = route(rk, rm, rk.shape[0])
        lk_r, lg_r, lm_r = [exchange(x).reshape(-1)
                            for x in (lkb, lgb, lmb)]
        rk_r, rg_r, rm_r = [exchange(x).reshape(-1)
                            for x in (rkb, rgb, rmb)]
        li, ri, valid, cnt, ovf = hash_join_nonunique(
            lk_r, lm_r, rk_r, rm_r, cap_l)
        count = jax.lax.psum(cnt, "data")
        shuffle_drop = jax.lax.psum(ldrop + rdrop, "data")
        overflow = (jax.lax.psum(ovf.astype(jnp.int32), "data")
                    + shuffle_drop) > 0
        return lg_r[li], rg_r[ri], valid, count, overflow

    return shard_map(
        body, mesh=mesh, in_specs=(P("data"),) * 4,
        out_specs=(P("data"), P("data"), P("data"), P(), P()))(
            lkeys, lmask, rkeys, rmask)


# --------------------------------------------------------------------------
# graph: dst-block-partitioned SpMV
# --------------------------------------------------------------------------


def _block_spmv(xs_local, blk_src, blk_dstl, blk_w, n_local: int):
    """One SpMV step over this shard's dst-block edges.  ``xs_local`` is
    the shard's slice of the source vector; the full vector is gathered
    (the per-iteration frontier all-gather), contributions are computed in
    the stable dst-block edge order, and pad edges (``dst_local ==
    n_local``) are dropped by the scatter."""
    xs = jax.lax.all_gather(xs_local, "data", tiled=True)
    return jax.ops.segment_sum(xs[blk_src] * blk_w, blk_dstl,
                               num_segments=n_local)


def sharded_pagerank(g: dict, iters: int, damping: float,
                     personalization, mesh):
    """Damped power iteration over the dst-block-partitioned graph: rank /
    out-degree / personalization all row(node)-partitioned, one frontier
    all-gather per iteration.  The teleport normalization sums the *fully
    gathered* personalization (not a psum of partials), so every float
    reduction matches the dense kernel's association — bitwise equal."""
    n = int(g["indptr"].shape[0]) - 1
    nd = data_axis_size(mesh)
    n_local = n // nd
    has_p = personalization is not None
    p = (personalization.astype(jnp.float32) if has_p
         else jnp.full((n,), 1.0 / n, jnp.float32))

    def body(p_l, deg_l, src_b, dst_b, w_b):
        if has_p:
            p_full = jax.lax.all_gather(p_l, "data", tiled=True)
            p0_l = p_l / jnp.maximum(jnp.sum(p_full), 1e-30)
        else:
            p0_l = p_l
        r_l = p0_l
        for _ in range(int(iters)):
            y_l = _block_spmv(r_l / deg_l, src_b, dst_b, w_b, n_local)
            r_l = (1.0 - damping) * p0_l + damping * y_l
        return r_l

    return shard_map(body, mesh=mesh, in_specs=(P("data"),) * 5,
                     out_specs=P("data"))(
        p, g["out_deg"], g["blk_src"], g["blk_dst_local"], g["blk_weights"])


def sharded_expand(g: dict, frontier, hops: int, mesh):
    """k-hop frontier expansion on the dst-block-partitioned SpMV: one
    all-gather per hop, bitwise equal to the dense expansion."""
    n = int(g["indptr"].shape[0]) - 1
    n_local = n // data_axis_size(mesh)

    def body(x_l, src_b, dst_b, w_b):
        x_l = x_l.astype(jnp.float32)
        for _ in range(int(hops)):
            x_l = _block_spmv(x_l, src_b, dst_b, w_b, n_local)
        return x_l

    return shard_map(body, mesh=mesh, in_specs=(P("data"),) * 4,
                     out_specs=P("data"))(
        frontier, g["blk_src"], g["blk_dst_local"], g["blk_weights"])


# --------------------------------------------------------------------------
# text: shard-local scoring + distributed top-k merge
# --------------------------------------------------------------------------


def sharded_tfidf_topk(corpus: dict, query, k: int, mesh):
    """Distributed top-k TF-IDF: score the doc-partitioned corpus shard-
    locally (bitwise: the stable doc-block posting selection preserves
    per-doc contribution order), take each shard's local top-k, then merge
    the fixed-capacity candidate lists by (score desc, doc asc) — exactly
    ``lax.top_k``'s ordering with lowest-index tie-breaking, so the merged
    result is bitwise equal to the dense top-k.

    Returns ``(ids, scores, valid)`` of length ``min(k, n_docs)``.
    """
    n_docs = int(corpus["doc_len"].shape[0])
    nd = data_axis_size(mesh)
    n_local = n_docs // nd
    k = min(int(k), n_docs)
    k_l = min(k, n_local)

    def body(len_l, idf, q, docl, term, tf):
        w = q.astype(jnp.float32) * idf
        contrib = w[term] * tf / len_l[jnp.clip(docl, 0, n_local - 1)]
        scores_l = jax.ops.segment_sum(contrib, docl,
                                       num_segments=n_local)
        vals, ids = jax.lax.top_k(scores_l, k_l)
        gids = (ids + jax.lax.axis_index("data") * n_local).astype(jnp.int32)
        return vals, gids

    vals, gids = shard_map(
        body, mesh=mesh,
        in_specs=(P("data"), P(), P(), P("data"), P("data"), P("data")),
        out_specs=(P("data"), P("data")))(
        corpus["doc_len"], corpus["idf"], query.astype(jnp.float32),
        corpus["blk_doc_local"], corpus["blk_term_ids"], corpus["blk_tf"])
    # fixed-capacity merge: (n_data * k_l) candidates -> global top-k,
    # ordered by (score desc, doc asc) = lax.top_k's tie-breaking
    order = jnp.lexsort((gids, -vals))[:k]
    return (gids[order], vals[order], jnp.ones((k,), jnp.bool_))
