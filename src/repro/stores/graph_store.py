"""CSR graph store: adjacency + frontier ops.

The store keeps the graph in CSR (``indptr``/``indices`` over source
vertices) plus the per-edge source expansion (``src``) so one sparse
matrix-vector product — the core of every frontier op — is

    y[v] = Σ_{e: dst[e]=v} x[src[e]] · w[e]

i.e. an XLA gather followed by a scatter-add.  Two scatter-add backends
exist: ``jax.ops.segment_sum`` (the portable fallback, any engine) and the
Pallas one-hot-matmul kernel (:mod:`.graph_kernels`), which the planner
offers as a candidate when the ``pallas`` engine is enabled.

Frontier ops built on the SpMV:

  * :func:`expand_frontier` — k-hop expansion of a weighted frontier;
  * :func:`pagerank`        — damped (optionally personalized) power
    iteration with out-degree normalization;
  * :func:`triangle_count`  — Σ(A ∘ A²)/6 over the densified adjacency
    (small-graph realization; the CSR stays the source of truth).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.ir import GraphT, ValidationError
from ..core.ledger import register_store_payload
from .graph_kernels import scatter_add_pallas


class GraphStore:
    """Host-side CSR container built from an edge list."""

    def __init__(self, indptr, indices, src, weights, n_nodes: int,
                 shards: int = 1):
        self.indptr = np.asarray(indptr, np.int32)
        self.indices = np.asarray(indices, np.int32)
        self.src = np.asarray(src, np.int32)
        self.weights = np.asarray(weights, np.float32)
        self.n_nodes = int(n_nodes)
        self.n_edges = int(self.indices.shape[0])
        self.shards = int(shards)
        if self.shards < 1:
            raise ValidationError(f"shards {self.shards} < 1")
        if self.n_nodes % self.shards:
            raise ValidationError(
                f"shards {self.shards} must divide n_nodes {self.n_nodes}; "
                f"pad the node domain (with_shards pads automatically)")
        # monotonic content version (parity with Column/Text stores): bumped
        # by any future mutation; the ledger snapshots it per payload so
        # consumers pinning stale payloads are flagged as leaks
        self.version = 0

    def with_shards(self, shards: int) -> "GraphStore":
        """This graph re-declared as dst-block partitioned over ``shards``
        mesh slices.  The node domain pads up to a shard multiple with
        isolated (edgeless) vertices; `payload()` then additionally carries
        the dst-block edge arrays the block-partitioned SpMV runs on."""
        n = self.n_nodes + (-self.n_nodes) % int(shards)
        indptr = self.indptr
        if n != self.n_nodes:
            pad = np.full(n - self.n_nodes, self.indptr[-1], np.int32)
            indptr = np.concatenate([self.indptr, pad])
        out = GraphStore(indptr, self.indices, self.src, self.weights, n,
                         shards=int(shards))
        out.version = self.version
        return out

    @classmethod
    def from_edges(cls, src, dst, n_nodes: int, weights=None,
                   symmetric: bool = False) -> "GraphStore":
        """Build CSR from COO edges.  ``symmetric=True`` mirrors every edge
        (undirected graphs — what triangle counting expects)."""
        src = np.asarray(src, np.int64)
        dst = np.asarray(dst, np.int64)
        if src.shape != dst.shape:
            raise ValidationError(f"edge arrays differ: {src.shape} vs "
                                  f"{dst.shape}")
        w = (np.ones(src.shape, np.float32) if weights is None
             else np.asarray(weights, np.float32))
        if w.shape != src.shape:
            raise ValidationError(
                f"weights shape {w.shape} != edges {src.shape}")
        if symmetric:
            src, dst, w = (np.concatenate([src, dst]),
                           np.concatenate([dst, src]),
                           np.concatenate([w, w]))
        if src.size and (src.min() < 0 or src.max() >= n_nodes
                         or dst.min() < 0 or dst.max() >= n_nodes):
            raise ValidationError("edge endpoint out of range")
        order = np.argsort(src, kind="stable")
        src, dst, w = src[order], dst[order], w[order]
        counts = np.bincount(src, minlength=n_nodes)
        indptr = np.concatenate([[0], np.cumsum(counts)])
        return cls(indptr, dst, src, w, n_nodes)

    @property
    def type(self) -> GraphT:
        return GraphT(self.n_nodes, self.n_edges,
                      weighted=bool((self.weights != 1.0).any()),
                      partitioning="block" if self.shards > 1 else None)

    def payload(self) -> dict:
        out_deg = np.maximum(np.diff(self.indptr), 1).astype(np.float32)
        out = {
            "indptr": jnp.asarray(self.indptr),
            "indices": jnp.asarray(self.indices),   # dst per edge
            "src": jnp.asarray(self.src),           # src per edge
            "weights": jnp.asarray(self.weights),
            "out_deg": jnp.asarray(out_deg),
        }
        if self.shards > 1:
            out.update(self._block_payload())
        register_store_payload(self, out, "graph_store")
        return out

    def _block_payload(self) -> dict:
        """Dst-block edge partition for the block-partitioned SpMV: shard d
        owns dst nodes ``[d*n/s, (d+1)*n/s)`` and exactly the edges landing
        there.  The selection is *stable* over the CSR (src-sorted) edge
        order, so within every dst segment the contribution order matches
        the dense SpMV — block-partitioned segment sums stay bitwise equal.
        Blocks pad to the max block edge count; pad slots carry
        ``dst_local = n_local`` (an out-of-range segment id: scatters drop
        it) and weight 0."""
        s, n = self.shards, self.n_nodes
        n_local = n // s
        block = self.indices // n_local                # dst block per edge
        counts = np.bincount(block, minlength=s)
        e_max = max(int(counts.max()) if counts.size else 0, 1)
        src_b = np.zeros((s, e_max), np.int32)
        dstl_b = np.full((s, e_max), n_local, np.int32)    # pad -> dropped
        w_b = np.zeros((s, e_max), np.float32)
        order = np.argsort(block, kind="stable")       # dst-block grouping
        starts = np.concatenate([[0], np.cumsum(counts)])
        for d in range(s):
            sel = order[starts[d]:starts[d + 1]]
            src_b[d, :sel.size] = self.src[sel]
            dstl_b[d, :sel.size] = self.indices[sel] - d * n_local
            w_b[d, :sel.size] = self.weights[sel]
        return {
            "blk_src": jnp.asarray(src_b.reshape(-1)),
            "blk_dst_local": jnp.asarray(dstl_b.reshape(-1)),
            "blk_weights": jnp.asarray(w_b.reshape(-1)),
        }


# --------------------------------------------------------------------------
# frontier kernels (pure functions over the payload)
# --------------------------------------------------------------------------


def _spmv(g: dict, x, scatter: Optional[Callable] = None):
    n = g["indptr"].shape[0] - 1
    vals = x[g["src"]] * g["weights"]
    if scatter is not None:
        return scatter(vals, g["indices"], n)
    return jax.ops.segment_sum(vals, g["indices"], num_segments=n)


def _pallas_scatter(interpret: bool) -> Callable:
    return lambda vals, dst, n: scatter_add_pallas(
        vals, dst, num_nodes=n, interpret=interpret)


def expand_frontier(g: dict, frontier, hops: int = 1,
                    use_pallas: bool = False, interpret: bool = True):
    """k-hop expansion: propagate frontier weight along edges ``hops``
    times.  One hop is exactly one SpMV."""
    scatter = _pallas_scatter(interpret) if use_pallas else None
    x = frontier.astype(jnp.float32)
    for _ in range(int(hops)):
        x = _spmv(g, x, scatter)
    return x


def _spmv_blockskip(src_b, dst_b, w_b, n: int, x, active_of):
    """One SpMV that skips edge blocks whose source nodes are all zero in
    ``x``.  Skipped edges would contribute exactly ``x[src]*w == +0.0``, so
    the result is bitwise identical to the dense SpMV (same contributions,
    same scatter order); the activity test is recomputed from the *current*
    frontier, so later hops skip less as the frontier densifies."""
    active = active_of(x)

    def body(acc, xs):
        s, d, w, act = xs

        def do(a):
            return a.at[d].add(x[s] * w)

        return jax.lax.cond(act, do, lambda a: a, acc), None

    y, _ = jax.lax.scan(body, jnp.zeros((n,), jnp.float32),
                        (src_b, dst_b, w_b, active))
    return y


def _blockskip_env(g: dict, block: int):
    """Edge-blocked CSR view + the O(1) per-block activity test shared by
    every block-skipping SpMV (frontier expansion, first-iteration
    PageRank).  Returns ``(src_b, dst_b, w_b, n, active_of)`` or None for
    an edgeless graph."""
    n = int(g["indptr"].shape[0]) - 1
    src, dst, w = g["src"], g["indices"], g["weights"]
    e = int(src.shape[0])
    if e == 0:
        return None
    b = max(8, min(int(block), e))
    pad = (-e) % b
    # padded edges carry weight 0 -> contribute exactly +0.0
    src_p = jnp.pad(src, (0, pad), constant_values=int(n - 1))
    dst_p = jnp.pad(dst, (0, pad))
    w_p = jnp.pad(w, (0, pad))
    nb = (e + pad) // b
    src_b = src_p.reshape(nb, b)
    dst_b = dst_p.reshape(nb, b)
    w_b = w_p.reshape(nb, b)
    lo = src_b.min(axis=1)
    hi = src_b.max(axis=1)

    def active_of(xc):
        nz = (xc != 0).astype(jnp.int32)
        prefix = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                  jnp.cumsum(nz)])
        return (prefix[hi + 1] - prefix[lo]) > 0

    return src_b, dst_b, w_b, n, active_of


def expand_frontier_blockskip(g: dict, frontier, hops: int = 1,
                              block: int = 2048):
    """Frontier expansion under a pushed selection mask: per-hop SpMV with
    edge-block skipping.  Edges are CSR-sorted by source, so a frontier
    whose support clusters (popular low-id hashtags, recent suffixes)
    leaves most blocks with no active source; a prefix-sum over the
    frontier's nonzero mask turns each block's source span into an O(1)
    activity test."""
    x = frontier.astype(jnp.float32)
    env = _blockskip_env(g, block)
    if env is None:
        n = int(g["indptr"].shape[0]) - 1
        return jnp.zeros((n,), jnp.float32) if hops else x
    src_b, dst_b, w_b, n, active_of = env
    for _ in range(int(hops)):
        x = _spmv_blockskip(src_b, dst_b, w_b, n, x, active_of)
    return x


def pagerank(g: dict, iters: int = 10, damping: float = 0.85,
             personalization=None, use_pallas: bool = False,
             interpret: bool = True, skip_first: bool = False,
             block: int = 2048):
    """Damped power iteration with out-degree normalization.

    ``skip_first=True`` is the personalization-sparsity pushdown: iteration
    0's SpMV input is exactly the (normalized) personalization vector, so
    when a pushed selection mask makes it sparse, the first iteration runs
    as a block-skipping SpMV driven by its nonzero support.  Skipped edges
    would contribute exactly ``+0.0``, so the result is bitwise identical
    to the dense iteration; later iterations (whose rank vector is dense
    after one propagation) stay on the dense SpMV."""
    scatter = _pallas_scatter(interpret) if use_pallas else None
    n = g["indptr"].shape[0] - 1
    if personalization is None:
        p0 = jnp.full((n,), 1.0 / n, jnp.float32)
    else:
        p = personalization.astype(jnp.float32)
        p0 = p / jnp.maximum(jnp.sum(p), 1e-30)
    env = (_blockskip_env(g, block)
           if skip_first and personalization is not None else None)
    r = p0
    for it in range(int(iters)):
        xs = r / g["out_deg"]
        if it == 0 and env is not None:
            src_b, dst_b, w_b, _n, active_of = env
            y = _spmv_blockskip(src_b, dst_b, w_b, n, xs, active_of)
        else:
            y = _spmv(g, xs, scatter)
        r = (1.0 - damping) * p0 + damping * y
    return r


def triangle_count(g: dict):
    """Triangles in the (symmetric, simple) graph: Σ(A ∘ A²)/6."""
    n = g["indptr"].shape[0] - 1
    a = jnp.zeros((n, n), jnp.float32).at[g["src"], g["indices"]].set(1.0)
    return jnp.sum(a * (a @ a)) / 6.0
