"""Tri-store foundation: the ``Store`` protocol and the three store engines.

AWESOME's tri-store registers a relational, a graph, and a text engine with
the mediator and plans *across* them (paper §2).  Here each store is

  * a **named engine** in the planner's engine registry — candidate
    generation and cost-model selection gate store candidates on the engine
    names exactly as they gate ``pallas`` kernels;
  * a **host-side container** implementing the :class:`Store` protocol: it
    owns the store's on-device representation (``payload()`` — a pytree of
    JAX arrays bound to a plan input at call time) and the ADIL type
    describing it (``type`` — TableT / GraphT / CorpusT, the metadata the
    cost model prices cross-engine movement with).

The executor binds stores positionally: a store is declared as a typed plan
input (``Analysis.table/graph/corpus``), and the caller passes
``store.payload()`` for that input name.  Planning never touches the data —
only the type metadata — so staged plans over stores cache and persist like
any other plan.
"""
from __future__ import annotations

from typing import Any, Protocol, runtime_checkable

from ..core.engines import register_engine
from ..core.ir import Type

# the three store engines of the paper's tri-store, registered alongside
# xla/pallas so planning options can name them (engines=("xla", "rel", ...))
REL_ENGINE = register_engine(
    "rel", "columnar relational store: struct-of-JAX-arrays tables with "
           "filter/project/hash-join/group-agg kernels")
GRAPH_ENGINE = register_engine(
    "graph", "CSR graph store: frontier expansion, PageRank iteration, "
             "triangle counting (segment_sum path; Pallas kernels register "
             "under the pallas engine)")
TEXT_ENGINE = register_engine(
    "text", "inverted-index text store: tokenized corpus with top-k TF-IDF "
            "scoring")

STORE_ENGINE_NAMES = ("rel", "graph", "text")


def store_engines(*, pallas: bool = False) -> tuple:
    """The engine tuple a tri-model analysis plans against: the interpreter
    engine, the three store engines, and optionally the Pallas kernels."""
    base = ("xla",) + STORE_ENGINE_NAMES
    return base + ("pallas",) if pallas else base


@runtime_checkable
class Store(Protocol):
    """What every store exposes to the planner and the executor."""

    @property
    def type(self) -> Type:
        """The ADIL data-model type (TableT/GraphT/CorpusT) of this store —
        the size metadata the cost model prices movement with."""
        ...

    def payload(self) -> Any:
        """The on-device representation: a pytree of JAX arrays, bound to
        this store's plan input at call time."""
        ...
