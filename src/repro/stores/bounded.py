"""BoundedRel: the capacity-bounded relation, the tri-store's one runtime
representation for every filtered / joined / top-k / grouped intermediate.

A relation at run time is a **fixed-shape struct-of-arrays** (one
``(capacity,)`` array per column) plus

  * ``valid``    — the per-row validity vector (the selection mask),
  * ``count``    — the traced number of valid rows (``valid.sum()``),
  * ``overflow`` — a traced flag: somewhere upstream, true results did not
    fit a declared capacity (a ``compact`` narrower than the survivor
    count, a ``bounded_join`` whose match total exceeded its bound) and
    rows were dropped.

This replaces the three ad-hoc conventions that grew up around static
shapes — ``_mask`` columns in the relational engine, ``valid=False``
overflow slots in ``text_topk`` results, and ``(values, valid)`` pairs from
``group_agg`` — with one abstraction every engine consumes and emits.
Cardinality is now *first-class*: the executor can observe ``count``
against ``capacity`` (selectivity feedback), the planner can insert
``compact`` where the expected count is far below capacity, and
``bounded_join`` can realize non-unique build keys behind a capacity bound
with an honest overflow flag.

``BoundedRel`` is a registered JAX pytree, so relations flow through
``jit``/``vmap``/``pure_callback`` like any other plan value.  It is also
dict-like (``rel["col"]``, ``rel["_mask"]``, iteration over column names
then ``"_mask"``) so existing callers that treated tables as column dicts
keep working unchanged.

Rows at indices ``>= count`` in a *compacted* relation (and rows with
``valid == False`` generally) carry placeholder values; every consumer must
weight by ``valid`` — exactly the discipline the old ``_mask`` convention
already required.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

MASK = "_mask"


@jax.tree_util.register_pytree_node_class
class BoundedRel:
    """Capacity-bounded relation: struct-of-arrays + valid + count.

    ``count`` is computed lazily from ``valid`` on first access (and
    materialized on pytree flattening, so jit boundaries always carry it):
    most intermediate relations in a plain execution never consume their
    count, and the eager O(capacity) reduction per operator would be pure
    overhead outside observation/compaction sites."""

    __slots__ = ("cols", "valid", "_count", "overflow")

    def __init__(self, cols: Dict[str, jnp.ndarray], valid,
                 count=None, overflow=None):
        self.cols = dict(cols)
        self.valid = valid
        self._count = count
        self.overflow = (jnp.asarray(False) if overflow is None
                         else overflow)

    @property
    def count(self):
        if self._count is None:
            self._count = jnp.sum(self.valid.astype(jnp.int32))
        return self._count

    # -- pytree protocol ---------------------------------------------------
    def tree_flatten(self):
        names = tuple(self.cols)
        return ((tuple(self.cols[n] for n in names), self.valid,
                 self.count, self.overflow), names)

    @classmethod
    def tree_unflatten(cls, names, children):
        col_vals, valid, count, overflow = children
        obj = object.__new__(cls)
        obj.cols = dict(zip(names, col_vals))
        obj.valid = valid
        obj._count = count
        obj.overflow = overflow
        return obj

    # -- dict-like surface (compat with the column-dict convention) --------
    def __getitem__(self, name: str):
        if name == MASK:
            return self.valid
        return self.cols[name]

    def __contains__(self, name: str) -> bool:
        return name == MASK or name in self.cols

    def __iter__(self):
        yield from self.cols
        yield MASK

    def keys(self):
        return tuple(self.cols) + (MASK,)

    def items(self):
        for k in self.cols:
            yield k, self.cols[k]
        yield MASK, self.valid

    # -- structure ---------------------------------------------------------
    @property
    def capacity(self) -> int:
        return int(self.valid.shape[0])

    def col_names(self) -> tuple:
        return tuple(self.cols)

    def with_cols(self, cols: Dict[str, jnp.ndarray]) -> "BoundedRel":
        """Same cardinality metadata over a different column set."""
        return BoundedRel(cols, self.valid, self.count, self.overflow)

    def narrowed(self, mask) -> "BoundedRel":
        """Conjoin a predicate mask: validity and count shrink, capacity
        and column storage do not (the masked-execution realization)."""
        valid = self.valid & mask
        return BoundedRel(self.cols, valid, None,
                          self.overflow)

    def __repr__(self):
        cols = ", ".join(self.cols)
        return (f"BoundedRel([{cols}]; capacity={self.capacity}, "
                f"count={self.count!r}, overflow={self.overflow!r})")


def as_bounded(value) -> BoundedRel:
    """Coerce a runtime table value to BoundedRel.  Accepts a BoundedRel
    (returned as-is) or the legacy column dict with an optional ``_mask``
    key (wrapped; missing mask means fully valid)."""
    if isinstance(value, BoundedRel):
        return value
    cols = {k: v for k, v in value.items() if k != MASK}
    if MASK in value:
        valid = value[MASK]
    else:
        any_col = next(iter(cols.values()))
        valid = jnp.ones(any_col.shape[:1], jnp.bool_)
    return BoundedRel(cols, valid)


def compact_rel(rel: BoundedRel, capacity: Optional[int] = None
                ) -> BoundedRel:
    """Stable prefix compaction: the valid rows, in their original order,
    moved to the front of a (possibly smaller) capacity.

    Static-shaped via ``jnp.nonzero(size=...)`` — the XLA realization of
    the prefix-sum compaction (the Pallas one-hot realization lives in
    :mod:`.masked_kernels`).  If more than ``capacity`` rows are valid the
    excess is dropped and ``overflow`` is raised; otherwise the result is
    value-identical to the masked relation (invalid slots replicate row 0
    with ``valid=False``, so every mask-weighted consumer sees the same
    contributions in the same order).
    """
    cap = rel.capacity if capacity is None else int(capacity)
    cap = max(1, min(cap, rel.capacity))
    (idx,) = jnp.nonzero(rel.valid, size=cap, fill_value=0)
    cols = {k: v[idx] for k, v in rel.cols.items()}
    count = jnp.minimum(rel.count, cap).astype(jnp.int32)
    valid = jnp.arange(cap, dtype=jnp.int32) < count
    overflow = rel.overflow | (rel.count > cap)
    return BoundedRel(cols, valid, count, overflow)
