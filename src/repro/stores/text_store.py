"""Inverted-index text store: tokenized corpus + top-k TF-IDF scoring.

The corpus is stored as the COO of its term-document matrix — one
``(doc_id, term_id, tf)`` triple per posting — plus per-document lengths
and the idf table.  Scoring a dense query vector is then one gather + one
segment-sum over the postings (static shapes, jittable):

    score[d] = Σ_{postings (d, t)}  q[t] · idf[t] · tf[d,t] / len[d]

followed by ``lax.top_k`` over documents.  The result is handed back as a
*relation* (a (k,)-row table of ``doc``/``score``) — cross-engine by
construction, which is what the planner's ``xfer`` placement operates on.
"""
from __future__ import annotations

from typing import Iterable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.ir import CorpusT, ValidationError


class TextStore:
    """Host-side container: tokenized documents -> inverted-index COO."""

    def __init__(self, doc_ids, term_ids, tf, doc_len, idf, vocab: int):
        self.doc_ids = np.asarray(doc_ids, np.int32)
        self.term_ids = np.asarray(term_ids, np.int32)
        self.tf = np.asarray(tf, np.float32)
        self.doc_len = np.asarray(doc_len, np.float32)
        self.idf = np.asarray(idf, np.float32)
        self.vocab = int(vocab)
        self.n_docs = int(self.doc_len.shape[0])
        self.n_postings = int(self.doc_ids.shape[0])

    @classmethod
    def from_docs(cls, docs: Sequence[Iterable[int]], vocab: int
                  ) -> "TextStore":
        """``docs``: one iterable of term ids per document."""
        doc_ids, term_ids, tfs = [], [], []
        doc_len = np.zeros(len(docs), np.float32)
        df = np.zeros(vocab, np.int64)
        for d, terms in enumerate(docs):
            terms = np.asarray(list(terms), np.int64)
            if terms.size and (terms.min() < 0 or terms.max() >= vocab):
                raise ValidationError(f"doc {d}: term id out of range")
            doc_len[d] = max(terms.size, 1)
            uniq, counts = np.unique(terms, return_counts=True)
            doc_ids.append(np.full(uniq.shape, d, np.int64))
            term_ids.append(uniq)
            tfs.append(counts)
            df[uniq] += 1
        doc_ids = np.concatenate(doc_ids) if doc_ids else np.zeros(0, np.int64)
        term_ids = (np.concatenate(term_ids) if term_ids
                    else np.zeros(0, np.int64))
        tfs = np.concatenate(tfs) if tfs else np.zeros(0, np.int64)
        idf = np.log((1.0 + len(docs)) / (1.0 + df)) + 1.0   # smoothed idf
        return cls(doc_ids, term_ids, tfs, doc_len, idf, vocab)

    @property
    def type(self) -> CorpusT:
        return CorpusT(self.n_docs, self.vocab, self.n_postings)

    def payload(self) -> dict:
        return {
            "doc_ids": jnp.asarray(self.doc_ids),
            "term_ids": jnp.asarray(self.term_ids),
            "tf": jnp.asarray(self.tf),
            "doc_len": jnp.asarray(self.doc_len),
            "idf": jnp.asarray(self.idf),
        }

    def query_vector(self, terms: Iterable[int]) -> np.ndarray:
        """Dense (vocab,) query term-count vector for :func:`tfidf_scores`."""
        q = np.zeros(self.vocab, np.float32)
        for t in terms:
            q[int(t)] += 1.0
        return q


# --------------------------------------------------------------------------
# scoring kernels (pure functions over the payload)
# --------------------------------------------------------------------------


def tfidf_scores(corpus: dict, query):
    """TF-IDF score of every document against a dense query vector."""
    w = query.astype(jnp.float32) * corpus["idf"]
    contrib = (w[corpus["term_ids"]] * corpus["tf"]
               / corpus["doc_len"][corpus["doc_ids"]])
    n_docs = corpus["doc_len"].shape[0]
    return jax.ops.segment_sum(contrib, corpus["doc_ids"],
                               num_segments=n_docs)


def tfidf_topk(corpus: dict, query, k: int):
    """Top-k documents by TF-IDF: ``(doc ids (k,), scores (k,))``."""
    scores = tfidf_scores(corpus, query)
    vals, ids = jax.lax.top_k(scores, int(k))
    return ids.astype(jnp.int32), vals
