"""Inverted-index text store: tokenized corpus + top-k TF-IDF scoring.

The corpus is stored as the COO of its term-document matrix — one
``(doc_id, term_id, tf)`` triple per posting — plus per-document lengths
and the idf table.  Scoring a dense query vector is then one gather + one
segment-sum over the postings (static shapes, jittable):

    score[d] = Σ_{postings (d, t)}  q[t] · idf[t] · tf[d,t] / len[d]

followed by ``lax.top_k`` over documents.  The result is handed back as a
*relation* (a (k,)-row table of ``doc``/``score``) — cross-engine by
construction, which is what the planner's ``xfer`` placement operates on.
"""
from __future__ import annotations

from typing import Iterable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.ir import CorpusT, ValidationError
from ..core.ledger import register_store_payload


class TextStore:
    """Host-side container: tokenized documents -> inverted-index COO."""

    def __init__(self, doc_ids, term_ids, tf, doc_len, idf, vocab: int,
                 shards: int = 1):
        self.doc_ids = np.asarray(doc_ids, np.int32)
        self.term_ids = np.asarray(term_ids, np.int32)
        self.tf = np.asarray(tf, np.float32)
        self.doc_len = np.asarray(doc_len, np.float32)
        self.idf = np.asarray(idf, np.float32)
        self.vocab = int(vocab)
        self.shards = int(shards)
        if self.shards < 1:
            raise ValidationError(f"shards {self.shards} < 1")
        if self.shards > 1 and self.doc_len.shape[0] % self.shards:
            # document-range partitioning needs equal doc blocks: pad with
            # empty docs (doc_len 1, no postings -> score exactly 0.0)
            pad = (-self.doc_len.shape[0]) % self.shards
            self.doc_len = np.concatenate(
                [self.doc_len, np.ones(pad, np.float32)])
        self.n_docs = int(self.doc_len.shape[0])
        self.n_postings = int(self.doc_ids.shape[0])
        # document frequency per term — kept so incremental appends can
        # *reindex* (recompute the idf table) without replaying the corpus
        self._df = (np.bincount(self.term_ids, minlength=self.vocab)
                    .astype(np.int64) if self.n_postings
                    else np.zeros(self.vocab, np.int64))
        self.version = 0

    @staticmethod
    def _index_docs(docs, vocab: int, first_doc: int):
        doc_ids, term_ids, tfs = [], [], []
        doc_len = np.zeros(len(docs), np.float32)
        df = np.zeros(vocab, np.int64)
        for d, terms in enumerate(docs):
            terms = np.asarray(list(terms), np.int64)
            if terms.size and (terms.min() < 0 or terms.max() >= vocab):
                raise ValidationError(
                    f"doc {first_doc + d}: term id out of range")
            doc_len[d] = max(terms.size, 1)
            uniq, counts = np.unique(terms, return_counts=True)
            doc_ids.append(np.full(uniq.shape, first_doc + d, np.int64))
            term_ids.append(uniq)
            tfs.append(counts)
            df[uniq] += 1
        cat = (lambda xs: np.concatenate(xs) if xs else np.zeros(0, np.int64))
        return cat(doc_ids), cat(term_ids), cat(tfs), doc_len, df

    @staticmethod
    def _idf(n_docs: int, df: np.ndarray) -> np.ndarray:
        return (np.log((1.0 + n_docs) / (1.0 + df)) + 1.0)  # smoothed idf

    @classmethod
    def from_docs(cls, docs: Sequence[Iterable[int]], vocab: int,
                  shards: int = 1) -> "TextStore":
        """``docs``: one iterable of term ids per document."""
        doc_ids, term_ids, tfs, doc_len, df = cls._index_docs(docs, vocab, 0)
        return cls(doc_ids, term_ids, tfs, doc_len, cls._idf(len(docs), df),
                   vocab, shards=shards)

    def with_shards(self, shards: int) -> "TextStore":
        """This corpus re-declared as document-partitioned over ``shards``
        mesh slices (pads the doc domain to a shard multiple)."""
        out = TextStore(self.doc_ids, self.term_ids, self.tf,
                        self.doc_len, self.idf, self.vocab, shards=shards)
        out.version = self.version
        return out

    def append(self, docs: Sequence[Iterable[int]]) -> "TextStore":
        """Append documents and reindex: postings extend (doc ids continue
        from ``n_docs``), document frequencies accumulate, and the idf
        table is recomputed over the grown corpus — identical to a fresh
        ``from_docs`` over the concatenated document list.  Bumps the
        monotonic ``version`` so cached plans priced against the old corpus
        statistics invalidate."""
        d_ids, t_ids, tfs, d_len, df = self._index_docs(
            docs, self.vocab, self.n_docs)
        self.doc_ids = np.concatenate([self.doc_ids,
                                       d_ids.astype(np.int32)])
        self.term_ids = np.concatenate([self.term_ids,
                                        t_ids.astype(np.int32)])
        self.tf = np.concatenate([self.tf, tfs.astype(np.float32)])
        self.doc_len = np.concatenate([self.doc_len, d_len])
        self._df += df
        self.n_docs += len(docs)
        self.n_postings = int(self.doc_ids.shape[0])
        self.idf = self._idf(self.n_docs, self._df).astype(np.float32)
        self.version += 1
        return self

    @property
    def type(self) -> CorpusT:
        return CorpusT(self.n_docs, self.vocab, self.n_postings,
                       "doc" if self.shards > 1 else None)

    def payload(self) -> dict:
        out = {
            "doc_ids": jnp.asarray(self.doc_ids),
            "term_ids": jnp.asarray(self.term_ids),
            "tf": jnp.asarray(self.tf),
            "doc_len": jnp.asarray(self.doc_len),
            "idf": jnp.asarray(self.idf),
        }
        if self.shards > 1:
            out.update(self._block_payload())
        register_store_payload(self, out, "text_store")
        return out

    def _block_payload(self) -> dict:
        """Doc-block posting partition for shard-local scoring: shard d owns
        docs ``[d*n/s, (d+1)*n/s)`` and their postings, padded per block to
        the max block posting count.  Pad slots carry ``doc_local =
        n_local`` (dropped by the scatter) and tf=0, and the stable
        selection preserves per-doc posting order, so shard-local
        segment sums stay bitwise equal to the dense scoring."""
        s, n = self.shards, self.n_docs
        n_local = n // s
        block = self.doc_ids // n_local
        counts = np.bincount(block, minlength=s)
        p_max = max(int(counts.max()) if counts.size else 0, 1)
        docl_b = np.full((s, p_max), n_local, np.int32)    # pad -> dropped
        term_b = np.zeros((s, p_max), np.int32)
        tf_b = np.zeros((s, p_max), np.float32)
        order = np.argsort(block, kind="stable")
        starts = np.concatenate([[0], np.cumsum(counts)])
        for d in range(s):
            sel = order[starts[d]:starts[d + 1]]
            docl_b[d, :sel.size] = self.doc_ids[sel] - d * n_local
            term_b[d, :sel.size] = self.term_ids[sel]
            tf_b[d, :sel.size] = self.tf[sel]
        return {
            "blk_doc_local": jnp.asarray(docl_b.reshape(-1)),
            "blk_term_ids": jnp.asarray(term_b.reshape(-1)),
            "blk_tf": jnp.asarray(tf_b.reshape(-1)),
        }

    def query_vector(self, terms: Iterable[int]) -> np.ndarray:
        """Dense (vocab,) query term-count vector for :func:`tfidf_scores`."""
        q = np.zeros(self.vocab, np.float32)
        for t in terms:
            q[int(t)] += 1.0
        return q


# --------------------------------------------------------------------------
# scoring kernels (pure functions over the payload)
# --------------------------------------------------------------------------


def tfidf_scores(corpus: dict, query):
    """TF-IDF score of every document against a dense query vector."""
    w = query.astype(jnp.float32) * corpus["idf"]
    contrib = (w[corpus["term_ids"]] * corpus["tf"]
               / corpus["doc_len"][corpus["doc_ids"]])
    n_docs = corpus["doc_len"].shape[0]
    return jax.ops.segment_sum(contrib, corpus["doc_ids"],
                               num_segments=n_docs)


def tfidf_topk(corpus: dict, query, k: int):
    """Top-k documents by TF-IDF: ``(doc ids, scores, valid)``, each of
    length ``min(k, n_docs)`` — ``k`` is clamped to the document count (the
    true result size) instead of crashing inside ``lax.top_k``."""
    scores = tfidf_scores(corpus, query)
    k = min(int(k), int(scores.shape[0]))
    vals, ids = jax.lax.top_k(scores, k)
    return ids.astype(jnp.int32), vals, jnp.ones((k,), jnp.bool_)


def masked_topk(scores, doc_mask, k: int):
    """Top-k over ``scores`` restricted to ``doc_mask``: masked docs score
    ``-inf`` before the top-k, and result rows whose slot holds a masked
    doc (k exceeds the unmasked count) come back with ``valid=False`` and
    score 0.0 (never ``-inf`` — a downstream mask-weighted aggregate would
    turn ``-inf * 0`` into NaN)."""
    k = min(int(k), int(scores.shape[0]))
    neg = jnp.where(doc_mask, scores, -jnp.inf)
    vals, ids = jax.lax.top_k(neg, k)
    valid = jnp.isfinite(vals)
    return ids.astype(jnp.int32), jnp.where(valid, vals, 0.0), valid


def tfidf_topk_masked(corpus: dict, query, doc_mask, k: int):
    """Dense masked scoring: score the whole corpus, then mask + top-k.
    The always-available realization of a pushed candidate-doc mask (and
    the bitwise reference the block-skipping path must reproduce)."""
    return masked_topk(tfidf_scores(corpus, query), doc_mask, k)


def tfidf_topk_blockskip(corpus: dict, query, doc_mask, k: int,
                         block: int = 8192):
    """Masked scoring that **skips posting blocks whose docs are all
    masked**.  Postings are doc-ordered, so a candidate mask over a
    clustered doc range (recency windows, popularity prefixes) leaves most
    blocks with zero unmasked docs; a prefix-sum over the mask turns each
    block's (first doc, last doc) span into an O(1) activity test, and
    ``lax.cond`` skips the gather + scatter-add for inactive blocks at run
    time.  Active blocks add the *same contributions in the same order* as
    the dense path, so results are bitwise identical.
    """
    n_docs = int(corpus["doc_len"].shape[0])
    doc_ids = corpus["doc_ids"]
    e = int(doc_ids.shape[0])
    if e == 0:
        return masked_topk(jnp.zeros((n_docs,), jnp.float32), doc_mask, k)
    w = query.astype(jnp.float32) * corpus["idf"]
    doc_len = corpus["doc_len"]

    b = max(8, min(int(block), e))
    pad = (-e) % b
    # padded postings carry tf=0 -> contribute exactly +0.0 to doc 0, and
    # pad doc_ids replicate the last (largest) doc id so block spans stay
    # sorted for the prefix-sum activity test
    d_p = jnp.pad(doc_ids, (0, pad), constant_values=int(n_docs - 1))
    t_p = jnp.pad(corpus["term_ids"], (0, pad))
    f_p = jnp.pad(corpus["tf"], (0, pad))
    nb = (e + pad) // b
    d_b = d_p.reshape(nb, b)
    t_b = t_p.reshape(nb, b)
    f_b = f_p.reshape(nb, b)

    # block activity: any unmasked doc inside the block's doc-id span.  The
    # span comes from a per-block min/max (one cheap int pass), so the test
    # stays sound even for corpora whose postings are not doc-sorted; the
    # mask prefix-sum makes each span query O(1).
    prefix = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(doc_mask.astype(jnp.int32))])
    active = (prefix[d_b.max(axis=1) + 1] - prefix[d_b.min(axis=1)]) > 0

    def body(acc, xs):
        d, t, f, act = xs

        def do(a):
            return a.at[d].add(w[t] * f / doc_len[d])

        return jax.lax.cond(act, do, lambda a: a, acc), None

    scores, _ = jax.lax.scan(body, jnp.zeros((n_docs,), jnp.float32),
                             (d_b, t_b, f_b, active))
    return masked_topk(scores, doc_mask, k)
