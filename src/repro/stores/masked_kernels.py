"""Pallas TPU superkernels for predicate-pushdown over the stores.

Both kernels reuse the one-hot-matmul trick of :mod:`.graph_kernels` — a
segment reduction expressed as ``(1, E_blk) @ (E_blk, N_blk)`` against the
mask ``seg[e] == id[n]``, an MXU-shaped contraction with no scatters — and
add the pushdown twist: every input row carries a *keep* weight derived
from the pushed selection mask, and a whole input block whose keep weights
are all zero is **skipped** (``pl.when``), so masked-out postings/rows cost
neither the elementwise pass nor the matmul.  The accumulator lives in VMEM
scratch across the (sequential, innermost) input-block grid axis, so each
output tile is written to HBM exactly once — the bytes the cost model
credits these candidates with.

Value gathers (``w[term_ids]``, ``doc_len[doc_ids]``, ``mask[doc_ids]``)
happen *outside* the kernels (XLA gathers are fine, the TPU kernel owns the
reduction side only), mirroring ``scatter_add_pallas``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# --------------------------------------------------------------------------
# masked TF-IDF scoring: gather + mask + segment-sum in one pass
# --------------------------------------------------------------------------


def _masked_tfidf_kernel(doc_ref, qidf_ref, tf_ref, dl_ref, keep_ref,
                         o_ref, acc_ref, *, block_d):
    eb = pl.program_id(1)

    @pl.when(eb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    keep = keep_ref[...]                     # (1, E_blk) float32 0/1
    doc_base = pl.program_id(0) * block_d    # grid queries stay outside when
    doc_ids = jax.lax.broadcasted_iota(
        jnp.int32, (1, block_d), 1) + doc_base

    @pl.when(jnp.any(keep > 0))
    def _compute():
        # fused elementwise (the "gather" products arrive pre-gathered):
        # contrib = q·idf[term] * tf / doc_len, zeroed for masked docs
        val = qidf_ref[...] * tf_ref[...] / dl_ref[...] * keep
        doc = doc_ref[...]
        onehot = (doc[0][:, None] == doc_ids[0][None, :]).astype(jnp.float32)
        acc_ref[...] += jnp.dot(val, onehot,
                                preferred_element_type=jnp.float32)

    @pl.when(eb == pl.num_programs(1) - 1)
    def _fin():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("n_docs", "block_e", "block_d",
                                    "interpret"))
def masked_tfidf_pallas(doc_ids, qidf_t, tf, dl_t, keep, *, n_docs: int,
                        block_e: int = 512, block_d: int = 256,
                        interpret: bool = True):
    """``score[d] = Σ_{postings e: doc[e]==d, keep[e]>0} qidf_t[e]·tf[e]/
    dl_t[e]`` — masked TF-IDF scores over pre-gathered posting features.

    Posting blocks whose ``keep`` weights are all zero are skipped inside
    the kernel.  Edge padding uses ``doc_ids = -1`` (matches no doc) with
    ``keep = 0``; doc padding is sliced off the result.
    """
    e = doc_ids.shape[0]
    if e == 0:
        return jnp.zeros((n_docs,), jnp.float32)
    be = min(block_e, max(8, e))
    bd = min(block_d, max(128, n_docs))
    e_pad = (-e) % be
    d_pad = (-n_docs) % bd

    def prep(a, fill=0):
        return jnp.pad(a, (0, e_pad), constant_values=fill)[None, :]

    doc_p = prep(doc_ids.astype(jnp.int32), -1)
    qidf_p = prep(qidf_t.astype(jnp.float32))
    tf_p = prep(tf.astype(jnp.float32))
    dl_p = prep(dl_t.astype(jnp.float32), 1)     # pad avoids 0/0
    keep_p = prep(keep.astype(jnp.float32))
    d_tot = n_docs + d_pad

    grid = (d_tot // bd, (e + e_pad) // be)
    espec = pl.BlockSpec((1, be), lambda db, ebk: (0, ebk))
    out = pl.pallas_call(
        functools.partial(_masked_tfidf_kernel, block_d=bd),
        grid=grid,
        in_specs=[espec] * 5,
        out_specs=pl.BlockSpec((1, bd), lambda db, ebk: (0, db)),
        out_shape=jax.ShapeDtypeStruct((1, d_tot), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, bd), jnp.float32)],
        interpret=interpret,
    )(doc_p, qidf_p, tf_p, dl_p, keep_p)
    return out[0, :n_docs]


# --------------------------------------------------------------------------
# prefix-sum compaction: valid rows scattered to their prefix positions
# --------------------------------------------------------------------------


def _compact_kernel(pos_ref, keep_ref, val_ref, o_ref, acc_ref, *, block_o):
    rb = pl.program_id(1)

    @pl.when(rb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    keep = keep_ref[...]                     # (1, R_blk) float32 0/1
    out_base = pl.program_id(0) * block_o    # grid queries stay outside when
    out_ids = jax.lax.broadcasted_iota(
        jnp.int32, (1, block_o), 1) + out_base

    @pl.when(jnp.any(keep > 0))
    def _compute():
        pos = pos_ref[...]
        # one-hot over destination slots: row i lands at its prefix-sum
        # position; invalid rows (keep=0, pos=-1) match no slot
        onehot = ((pos[0][:, None] == out_ids[0][None, :])
                  .astype(jnp.float32) * keep[0][:, None])
        acc_ref[...] += jnp.dot(val_ref[...], onehot,
                                preferred_element_type=jnp.float32)

    @pl.when(rb == pl.num_programs(1) - 1)
    def _fin():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("out_capacity", "block_r", "block_o",
                                    "interpret"))
def compact_prefix_pallas(vals, pos, keep, *, out_capacity: int,
                          block_r: int = 512, block_o: int = 256,
                          interpret: bool = True):
    """Prefix-sum compaction of ``C`` stacked value rows: ``out[c, j] =
    vals[c, i]`` for the row ``i`` whose exclusive mask prefix-sum is ``j``
    (``pos = cumsum(valid) - 1``, computed outside in XLA; the kernel owns
    the scatter side as a destination-one-hot matmul, mirroring the other
    kernels' split).  Row blocks whose ``keep`` weights are all zero are
    skipped.  Values pass through one multiply by 1.0, so float columns are
    bit-exact and integer columns are exact up to 2^24 (the planner's
    candidate gate keeps this kernel off wider keys).

    Row padding uses ``pos = -1`` (matches no slot) with ``keep = 0``;
    positions beyond ``out_capacity`` fall outside every block's id range
    and drop — exactly the capacity-overflow semantics of ``compact``.
    """
    c, r = vals.shape
    if r == 0 or out_capacity == 0:
        return jnp.zeros((c, out_capacity), jnp.float32)
    br = min(block_r, max(8, r))
    bo = min(block_o, max(128, out_capacity))
    r_pad = (-r) % br
    o_pad = (-out_capacity) % bo

    pos_p = jnp.pad(pos.astype(jnp.int32), (0, r_pad),
                    constant_values=-1)[None, :]
    keep_p = jnp.pad(keep.astype(jnp.float32), (0, r_pad))[None, :]
    val_p = jnp.pad(vals.astype(jnp.float32), ((0, 0), (0, r_pad)))
    o_tot = out_capacity + o_pad

    grid = (o_tot // bo, (r + r_pad) // br)
    rspec = pl.BlockSpec((1, br), lambda ob, rbk: (0, rbk))
    out = pl.pallas_call(
        functools.partial(_compact_kernel, block_o=bo),
        grid=grid,
        in_specs=[rspec, rspec, pl.BlockSpec((c, br), lambda ob, rbk: (0, rbk))],
        out_specs=pl.BlockSpec((c, bo), lambda ob, rbk: (0, ob)),
        out_shape=jax.ShapeDtypeStruct((c, o_tot), jnp.float32),
        scratch_shapes=[pltpu.VMEM((c, bo), jnp.float32)],
        interpret=interpret,
    )(pos_p, keep_p, val_p)
    return out[:, :out_capacity]


# --------------------------------------------------------------------------
# hash-join probe: unique-key build side compared on the MXU
# --------------------------------------------------------------------------


def _join_probe_kernel(lk_ref, rk_ref, rkeep_ref, o_ref):
    # (P_blk, N_build) key-equality one-hot, masked build rows excluded
    eq = ((lk_ref[...][0][:, None] == rk_ref[...][0][None, :])
          .astype(jnp.float32) * rkeep_ref[...][0][None, :])
    nb = rk_ref.shape[1]
    jvec = jax.lax.broadcasted_iota(jnp.int32, (nb, 1), 0).astype(jnp.float32)
    m = jnp.concatenate([jvec, jnp.ones((nb, 1), jnp.float32)], axis=1)
    # one matmul: col 0 = matched build index, col 1 = match count (0/1)
    acc = jnp.dot(eq, m, preferred_element_type=jnp.float32)
    o_ref[...] = acc.T


@functools.partial(jax.jit,
                   static_argnames=("block_p", "interpret"))
def join_probe_pallas(lkeys, rkeys, rvalid, *, block_p: int = 512,
                      interpret: bool = True):
    """Hash-join probe against a **unique-key** build side, realized as an
    MXU key-equality contraction: for each probe key, the matching build
    row index and a match flag.  Masked (invalid) build rows never match.

    The whole build side rides in one VMEM block, which is exactly why the
    planner gates this candidate on the build side's *expected count*: a
    capacity-bounded build (a compacted filter result, a top-k relation)
    fits; a full fact table does not.

    Returns ``(idx, matched)`` with ``idx.shape == lkeys.shape`` — bitwise
    the indices :func:`~repro.stores.column_store.hash_join` produces for
    matched rows (unmatched rows report index 0).
    """
    p = int(lkeys.shape[0])
    nr = int(rkeys.shape[0])
    if p == 0 or nr == 0:
        return (jnp.zeros((p,), jnp.int32), jnp.zeros((p,), jnp.bool_))
    bp = min(block_p, max(8, p))
    p_pad = (-p) % bp
    nr_pad = (-nr) % 128

    lk_p = jnp.pad(lkeys.astype(jnp.int32), (0, p_pad))[None, :]
    rk_p = jnp.pad(rkeys.astype(jnp.int32), (0, nr_pad))[None, :]
    rkeep = jnp.pad(rvalid.astype(jnp.float32), (0, nr_pad))[None, :]

    grid = ((p + p_pad) // bp,)
    bspec = pl.BlockSpec((1, nr + nr_pad), lambda pb: (0, 0))
    out = pl.pallas_call(
        _join_probe_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, bp), lambda pb: (0, pb)), bspec, bspec],
        out_specs=pl.BlockSpec((2, bp), lambda pb: (0, pb)),
        out_shape=jax.ShapeDtypeStruct((2, p + p_pad), jnp.float32),
        interpret=interpret,
    )(lk_p, rk_p, rkeep)
    idx = out[0, :p].astype(jnp.int32)
    matched = out[1, :p] > 0
    return jnp.where(matched, idx, 0), matched


# --------------------------------------------------------------------------
# masked segment aggregate: group-by sum + count in one pass
# --------------------------------------------------------------------------


def _masked_segagg_kernel(key_ref, val_ref, mw_ref, o_ref, acc_ref,
                          *, block_g):
    rb = pl.program_id(1)

    @pl.when(rb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    mw = mw_ref[...]                         # (1, R_blk) float32 0/1
    group_base = pl.program_id(0) * block_g  # grid queries stay outside when
    group_ids = jax.lax.broadcasted_iota(
        jnp.int32, (1, block_g), 1) + group_base

    @pl.when(jnp.any(mw > 0))
    def _compute():
        key = key_ref[...]
        onehot = (key[0][:, None] == group_ids[0][None, :]).astype(
            jnp.float32)
        # row 0: mask-weighted sums, row 1: mask counts — one matmul each,
        # sharing the one-hot tile
        stacked = jnp.concatenate([val_ref[...] * mw, mw], axis=0)
        acc_ref[...] += jnp.dot(stacked, onehot,
                                preferred_element_type=jnp.float32)

    @pl.when(rb == pl.num_programs(1) - 1)
    def _fin():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("num_groups", "block_r", "block_g",
                                    "interpret"))
def masked_segment_agg_pallas(vals, keys, maskw, *, num_groups: int,
                              block_r: int = 512, block_g: int = 256,
                              interpret: bool = True):
    """Mask-weighted group-by: ``(sums, counts)`` per group id in one
    kernel pass, skipping row blocks whose mask weights are all zero.

    Row padding uses ``keys = -1`` (matches no group) with ``maskw = 0``;
    group padding is sliced off.  ``mean`` is ``sums / max(counts, 1)``
    outside the kernel; ``max`` is not expressible as a one-hot matmul and
    keeps the segment-max fallback.
    """
    r = vals.shape[0]
    if r == 0:
        z = jnp.zeros((num_groups,), jnp.float32)
        return z, z
    br = min(block_r, max(8, r))
    bg = min(block_g, max(128, num_groups))
    r_pad = (-r) % br
    g_pad = (-num_groups) % bg

    key_p = jnp.pad(keys.astype(jnp.int32), (0, r_pad),
                    constant_values=-1)[None, :]
    val_p = jnp.pad(vals.astype(jnp.float32), (0, r_pad))[None, :]
    mw_p = jnp.pad(maskw.astype(jnp.float32), (0, r_pad))[None, :]
    g_tot = num_groups + g_pad

    grid = (g_tot // bg, (r + r_pad) // br)
    rspec = pl.BlockSpec((1, br), lambda gb, rbk: (0, rbk))
    out = pl.pallas_call(
        functools.partial(_masked_segagg_kernel, block_g=bg),
        grid=grid,
        in_specs=[rspec] * 3,
        out_specs=pl.BlockSpec((2, bg), lambda gb, rbk: (0, gb)),
        out_shape=jax.ShapeDtypeStruct((2, g_tot), jnp.float32),
        scratch_shapes=[pltpu.VMEM((2, bg), jnp.float32)],
        interpret=interpret,
    )(key_p, val_p, mw_p)
    return out[0, :num_groups], out[1, :num_groups]
