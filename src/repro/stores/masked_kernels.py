"""Pallas TPU superkernels for predicate-pushdown over the stores.

Both kernels reuse the one-hot-matmul trick of :mod:`.graph_kernels` — a
segment reduction expressed as ``(1, E_blk) @ (E_blk, N_blk)`` against the
mask ``seg[e] == id[n]``, an MXU-shaped contraction with no scatters — and
add the pushdown twist: every input row carries a *keep* weight derived
from the pushed selection mask, and a whole input block whose keep weights
are all zero is **skipped** (``pl.when``), so masked-out postings/rows cost
neither the elementwise pass nor the matmul.  The accumulator lives in VMEM
scratch across the (sequential, innermost) input-block grid axis, so each
output tile is written to HBM exactly once — the bytes the cost model
credits these candidates with.

Value gathers (``w[term_ids]``, ``doc_len[doc_ids]``, ``mask[doc_ids]``)
happen *outside* the kernels (XLA gathers are fine, the TPU kernel owns the
reduction side only), mirroring ``scatter_add_pallas``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# --------------------------------------------------------------------------
# masked TF-IDF scoring: gather + mask + segment-sum in one pass
# --------------------------------------------------------------------------


def _masked_tfidf_kernel(doc_ref, qidf_ref, tf_ref, dl_ref, keep_ref,
                         o_ref, acc_ref, *, block_d):
    eb = pl.program_id(1)

    @pl.when(eb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    keep = keep_ref[...]                     # (1, E_blk) float32 0/1
    doc_base = pl.program_id(0) * block_d    # grid queries stay outside when
    doc_ids = jax.lax.broadcasted_iota(
        jnp.int32, (1, block_d), 1) + doc_base

    @pl.when(jnp.any(keep > 0))
    def _compute():
        # fused elementwise (the "gather" products arrive pre-gathered):
        # contrib = q·idf[term] * tf / doc_len, zeroed for masked docs
        val = qidf_ref[...] * tf_ref[...] / dl_ref[...] * keep
        doc = doc_ref[...]
        onehot = (doc[0][:, None] == doc_ids[0][None, :]).astype(jnp.float32)
        acc_ref[...] += jnp.dot(val, onehot,
                                preferred_element_type=jnp.float32)

    @pl.when(eb == pl.num_programs(1) - 1)
    def _fin():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("n_docs", "block_e", "block_d",
                                    "interpret"))
def masked_tfidf_pallas(doc_ids, qidf_t, tf, dl_t, keep, *, n_docs: int,
                        block_e: int = 512, block_d: int = 256,
                        interpret: bool = True):
    """``score[d] = Σ_{postings e: doc[e]==d, keep[e]>0} qidf_t[e]·tf[e]/
    dl_t[e]`` — masked TF-IDF scores over pre-gathered posting features.

    Posting blocks whose ``keep`` weights are all zero are skipped inside
    the kernel.  Edge padding uses ``doc_ids = -1`` (matches no doc) with
    ``keep = 0``; doc padding is sliced off the result.
    """
    e = doc_ids.shape[0]
    if e == 0:
        return jnp.zeros((n_docs,), jnp.float32)
    be = min(block_e, max(8, e))
    bd = min(block_d, max(128, n_docs))
    e_pad = (-e) % be
    d_pad = (-n_docs) % bd

    def prep(a, fill=0):
        return jnp.pad(a, (0, e_pad), constant_values=fill)[None, :]

    doc_p = prep(doc_ids.astype(jnp.int32), -1)
    qidf_p = prep(qidf_t.astype(jnp.float32))
    tf_p = prep(tf.astype(jnp.float32))
    dl_p = prep(dl_t.astype(jnp.float32), 1)     # pad avoids 0/0
    keep_p = prep(keep.astype(jnp.float32))
    d_tot = n_docs + d_pad

    grid = (d_tot // bd, (e + e_pad) // be)
    espec = pl.BlockSpec((1, be), lambda db, ebk: (0, ebk))
    out = pl.pallas_call(
        functools.partial(_masked_tfidf_kernel, block_d=bd),
        grid=grid,
        in_specs=[espec] * 5,
        out_specs=pl.BlockSpec((1, bd), lambda db, ebk: (0, db)),
        out_shape=jax.ShapeDtypeStruct((1, d_tot), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, bd), jnp.float32)],
        interpret=interpret,
    )(doc_p, qidf_p, tf_p, dl_p, keep_p)
    return out[0, :n_docs]


# --------------------------------------------------------------------------
# masked segment aggregate: group-by sum + count in one pass
# --------------------------------------------------------------------------


def _masked_segagg_kernel(key_ref, val_ref, mw_ref, o_ref, acc_ref,
                          *, block_g):
    rb = pl.program_id(1)

    @pl.when(rb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    mw = mw_ref[...]                         # (1, R_blk) float32 0/1
    group_base = pl.program_id(0) * block_g  # grid queries stay outside when
    group_ids = jax.lax.broadcasted_iota(
        jnp.int32, (1, block_g), 1) + group_base

    @pl.when(jnp.any(mw > 0))
    def _compute():
        key = key_ref[...]
        onehot = (key[0][:, None] == group_ids[0][None, :]).astype(
            jnp.float32)
        # row 0: mask-weighted sums, row 1: mask counts — one matmul each,
        # sharing the one-hot tile
        stacked = jnp.concatenate([val_ref[...] * mw, mw], axis=0)
        acc_ref[...] += jnp.dot(stacked, onehot,
                                preferred_element_type=jnp.float32)

    @pl.when(rb == pl.num_programs(1) - 1)
    def _fin():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("num_groups", "block_r", "block_g",
                                    "interpret"))
def masked_segment_agg_pallas(vals, keys, maskw, *, num_groups: int,
                              block_r: int = 512, block_g: int = 256,
                              interpret: bool = True):
    """Mask-weighted group-by: ``(sums, counts)`` per group id in one
    kernel pass, skipping row blocks whose mask weights are all zero.

    Row padding uses ``keys = -1`` (matches no group) with ``maskw = 0``;
    group padding is sliced off.  ``mean`` is ``sums / max(counts, 1)``
    outside the kernel; ``max`` is not expressible as a one-hot matmul and
    keeps the segment-max fallback.
    """
    r = vals.shape[0]
    if r == 0:
        z = jnp.zeros((num_groups,), jnp.float32)
        return z, z
    br = min(block_r, max(8, r))
    bg = min(block_g, max(128, num_groups))
    r_pad = (-r) % br
    g_pad = (-num_groups) % bg

    key_p = jnp.pad(keys.astype(jnp.int32), (0, r_pad),
                    constant_values=-1)[None, :]
    val_p = jnp.pad(vals.astype(jnp.float32), (0, r_pad))[None, :]
    mw_p = jnp.pad(maskw.astype(jnp.float32), (0, r_pad))[None, :]
    g_tot = num_groups + g_pad

    grid = (g_tot // bg, (r + r_pad) // br)
    rspec = pl.BlockSpec((1, br), lambda gb, rbk: (0, rbk))
    out = pl.pallas_call(
        functools.partial(_masked_segagg_kernel, block_g=bg),
        grid=grid,
        in_specs=[rspec] * 3,
        out_specs=pl.BlockSpec((2, bg), lambda gb, rbk: (0, gb)),
        out_shape=jax.ShapeDtypeStruct((2, g_tot), jnp.float32),
        scratch_shapes=[pltpu.VMEM((2, bg), jnp.float32)],
        interpret=interpret,
    )(key_p, val_p, mw_p)
    return out[0, :num_groups], out[1, :num_groups]
