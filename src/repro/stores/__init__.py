"""AWESOME tri-store: columnar relational, CSR graph, and inverted-text
stores behind one Store protocol, registered as planner engines.

Importing this package registers the ``rel``/``graph``/``text`` engines and
their physical-op implementations (``runtime``), so any module that plans
or executes tri-model workloads just imports ``repro.stores``.
"""
from .base import (GRAPH_ENGINE, REL_ENGINE, STORE_ENGINE_NAMES, TEXT_ENGINE,
                   Store, store_engines)
from .bounded import BoundedRel, as_bounded, compact_rel
from .column_store import ColumnStore
from .graph_store import GraphStore
from .text_store import TextStore
from . import runtime as _runtime  # noqa: F401  (impl registration)

__all__ = [
    "BoundedRel", "as_bounded", "compact_rel",
    "ColumnStore", "GraphStore", "TextStore", "Store", "store_engines",
    "STORE_ENGINE_NAMES", "REL_ENGINE", "GRAPH_ENGINE", "TEXT_ENGINE",
]
