"""Pure-NumPy references for the store kernels.

Used by the property tests (and nothing else): every JAX/Pallas store
kernel must agree with the straightforward NumPy computation below.
"""
from __future__ import annotations

import numpy as np


def hash_join_ref(lkeys, rkeys):
    """Reference equi-join probe against a unique-key build side."""
    lkeys = np.asarray(lkeys)
    rkeys = np.asarray(rkeys)
    lut = {int(k): i for i, k in enumerate(rkeys)}
    idx = np.zeros(lkeys.shape, np.int64)
    matched = np.zeros(lkeys.shape, bool)
    for i, k in enumerate(lkeys):
        j = lut.get(int(k))
        if j is not None:
            idx[i] = j
            matched[i] = True
    return idx, matched


def join_probe_ref(lkeys, rkeys, rvalid):
    """Reference unique-build probe that excludes invalid build rows:
    matches :func:`repro.stores.masked_kernels.join_probe_pallas`
    (unmatched probe rows report index 0)."""
    lkeys = np.asarray(lkeys)
    lut = {int(k): i for i, k in enumerate(np.asarray(rkeys))
           if bool(np.asarray(rvalid)[i])}
    idx = np.zeros(lkeys.shape, np.int64)
    matched = np.zeros(lkeys.shape, bool)
    for i, k in enumerate(lkeys):
        j = lut.get(int(k))
        if j is not None:
            idx[i] = j
            matched[i] = True
    return idx, matched


def bounded_join_ref(lkeys, lmask, rkeys, rmask, capacity):
    """Reference non-unique-build equi-join into a capacity-bounded,
    validity-prefixed output.

    Output slots enumerate matches by probe row and, within one probe row,
    by the build side's stable (key, original index) order — exactly the
    order :func:`repro.stores.column_store.hash_join_nonunique` produces.
    Returns ``(lidx, ridx, valid, count, overflow)``.
    """
    lkeys = np.asarray(lkeys)
    rkeys = np.asarray(rkeys)
    lmask = np.asarray(lmask, bool)
    rmask = np.asarray(rmask, bool)
    order = np.argsort(rkeys, kind="stable")
    pairs = []
    for i in range(lkeys.shape[0]):
        if not lmask[i]:
            continue
        for r in order:
            if rmask[r] and int(rkeys[r]) == int(lkeys[i]):
                pairs.append((i, int(r)))
    total = len(pairs)
    count = min(total, capacity)
    lidx = np.zeros(capacity, np.int64)
    ridx = np.zeros(capacity, np.int64)
    valid = np.zeros(capacity, bool)
    for j, (i, r) in enumerate(pairs[:capacity]):
        lidx[j], ridx[j], valid[j] = i, r, True
    return lidx, ridx, valid, count, total > capacity


def compact_ref(cols, valid, capacity):
    """Reference stable prefix compaction of a column dict: valid rows in
    original order, truncated to ``capacity`` (overflow flagged).  Invalid
    output slots replicate row 0, mirroring the gather realization."""
    valid = np.asarray(valid, bool)
    idx = np.flatnonzero(valid)
    overflow = idx.shape[0] > capacity
    idx = idx[:capacity]
    count = idx.shape[0]
    pad = np.zeros(capacity - count, np.int64)
    take = np.concatenate([idx, pad]).astype(np.int64)
    out = {k: np.asarray(v)[take] for k, v in cols.items()}
    out_valid = np.arange(capacity) < count
    return out, out_valid, count, overflow


def group_agg_ref(values, keys, num_groups, mask, fn):
    """Reference mask-respecting groupby aggregate.

    ``fn="max"`` returns ``(values, valid)`` — an all-masked group is
    *invalid* (value slot 0.0), never conflated with a true max of 0.0;
    mirrors :func:`repro.stores.column_store.group_agg`.
    """
    keys = np.asarray(keys)
    mask = np.asarray(mask, bool)
    out = np.zeros(num_groups, np.float64)
    valid = np.zeros(num_groups, bool)
    for g in range(num_groups):
        sel = (keys == g) & mask
        if fn == "count":
            out[g] = sel.sum()
            continue
        v = np.asarray(values, np.float64)[sel]
        if v.size == 0:
            out[g] = 0.0
        elif fn == "sum":
            out[g] = v.sum()
        elif fn == "mean":
            out[g] = v.mean()
        elif fn == "max":
            out[g] = v.max()
            valid[g] = np.isfinite(out[g])
        else:
            raise ValueError(fn)
    if fn == "max":
        return np.where(valid, out, 0.0).astype(np.float32), valid
    return out.astype(np.float32)


def spmv_ref(src, dst, weights, n_nodes, x):
    """y[v] = sum over edges (u -> v) of x[u] * w."""
    y = np.zeros(n_nodes, np.float64)
    np.add.at(y, np.asarray(dst), np.asarray(x, np.float64)[src]
              * np.asarray(weights, np.float64))
    return y


def expand_ref(src, dst, weights, n_nodes, frontier, hops=1):
    x = np.asarray(frontier, np.float64)
    for _ in range(hops):
        x = spmv_ref(src, dst, weights, n_nodes, x)
    return x


def pagerank_ref(src, dst, weights, n_nodes, iters=10, damping=0.85,
                 personalization=None):
    counts = np.bincount(np.asarray(src), minlength=n_nodes)
    out_deg = np.maximum(counts, 1).astype(np.float64)
    if personalization is None:
        p0 = np.full(n_nodes, 1.0 / n_nodes)
    else:
        p = np.asarray(personalization, np.float64)
        p0 = p / max(p.sum(), 1e-30)
    r = p0.copy()
    for _ in range(iters):
        r = (1 - damping) * p0 + damping * spmv_ref(
            src, dst, weights, n_nodes, r / out_deg)
    return r


def triangle_count_ref(src, dst, n_nodes):
    a = np.zeros((n_nodes, n_nodes))
    a[np.asarray(src), np.asarray(dst)] = 1.0
    return float((a * (a @ a)).sum() / 6.0)


def tfidf_scores_ref(doc_ids, term_ids, tf, doc_len, idf, query):
    scores = np.zeros(len(doc_len), np.float64)
    q = np.asarray(query, np.float64)
    for d, t, f in zip(doc_ids, term_ids, np.asarray(tf, np.float64)):
        scores[d] += q[t] * idf[t] * f / doc_len[d]
    return scores


def masked_tfidf_scores_ref(doc_ids, term_ids, tf, doc_len, idf, query,
                            doc_mask):
    """Masked scoring: only unmasked docs accumulate (masked stay 0)."""
    scores = tfidf_scores_ref(doc_ids, term_ids, tf, doc_len, idf, query)
    return np.where(np.asarray(doc_mask, bool), scores, 0.0)


def masked_topk_ref(scores, doc_mask, k):
    """Reference masked top-k: ``(doc ids, scores, valid)`` of length
    ``min(k, n)``; slots past the unmasked count are invalid with score 0,
    ties broken by lowest doc id (matches ``lax.top_k``)."""
    scores = np.asarray(scores, np.float32)
    m = np.asarray(doc_mask, bool)
    k = min(int(k), scores.shape[0])
    neg = np.where(m, scores, -np.inf).astype(np.float32)
    ids = np.argsort(-neg, kind="stable")[:k]
    vals = neg[ids]
    valid = np.isfinite(vals)
    return (ids.astype(np.int32), np.where(valid, vals, 0.0).astype(
        np.float32), valid)


def masked_segment_agg_ref(vals, keys, maskw, num_groups):
    """Reference mask-weighted group-by ``(sums, counts)``."""
    sums = np.zeros(num_groups, np.float64)
    counts = np.zeros(num_groups, np.float64)
    for v, g, w in zip(np.asarray(vals, np.float64), np.asarray(keys),
                       np.asarray(maskw, np.float64)):
        sums[g] += v * w
        counts[g] += w
    return sums.astype(np.float32), counts.astype(np.float32)
