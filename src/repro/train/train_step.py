"""Train-step builder: planned forward + grads + optimizer + microbatching.

The buffering decision (§5.3) drives gradient accumulation: when streaming
is on, the step scans over microbatches — the live activation set shrinks by
the microbatch factor (the paper's −37 % heap result) and XLA can overlap
each microbatch's reduce-scatter with the next one's backward (structural
compute/comm overlap).

Mixed precision: params live in fp32 ("master"), compute casts to the
config dtype, and ``grad_dtype`` controls the reduction precision (bf16 =
2× collective-byte compression, see optim.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from ..core.executor import PlannedFunction
from .optim import clip_by_global_norm, make_optimizer


@dataclass
class TrainState:
    step: jax.Array
    params: Any
    opt_state: Any

    def tree_flatten(self):
        return (self.step, self.params, self.opt_state), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node(
    TrainState, TrainState.tree_flatten, TrainState.tree_unflatten)


def make_train_step(fwd: PlannedFunction, optimizer, *,
                    num_microbatches: int = 1,
                    grad_dtype: str = "float32",
                    clip_norm: float = 1.0,
                    positions_fn: Optional[Callable] = None):
    """Returns step(state, batch) -> (state, metrics).

    ``batch`` is the dict of plan inputs; microbatching slices every leaf on
    axis 0 into ``num_microbatches`` slices and accumulates grads.
    """

    def loss_fn(params, mb):
        if grad_dtype != "float32":
            cparams = jax.tree.map(
                lambda p: p.astype(grad_dtype)
                if p.dtype == jnp.float32 and p.ndim >= 2 else p, params)
        else:
            cparams = params
        aux = {}
        if positions_fn is not None:
            aux["positions"] = positions_fn(mb)
        loss = fwd(cparams, mb, aux)
        return loss

    grad_fn = jax.value_and_grad(loss_fn)

    def step(state: TrainState, batch: dict):
        if num_microbatches <= 1:
            loss, grads = grad_fn(state.params, batch)
        else:
            def slice_mb(i):
                return jax.tree.map(
                    lambda x: jax.lax.dynamic_slice_in_dim(
                        x, i * (x.shape[0] // num_microbatches),
                        x.shape[0] // num_microbatches, axis=0), batch)

            def body(carry, i):
                acc, lsum = carry
                l, g = grad_fn(state.params, slice_mb(i))
                acc = jax.tree.map(jnp.add, acc, g)
                return (acc, lsum + l), None

            # accumulate in the gradient's own dtype (= the param dtype:
            # grads of fp32 masters are fp32, of bf16 live params bf16)
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype),
                                 state.params)
            (grads, lsum), _ = jax.lax.scan(
                body, (zeros, jnp.zeros((), jnp.float32)),
                jnp.arange(num_microbatches))
            grads = jax.tree.map(
                lambda g: (g / num_microbatches).astype(g.dtype), grads)
            loss = lsum / num_microbatches

        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        new_params, new_opt = optimizer.update(grads, state.opt_state,
                                               state.params)
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "step": state.step + 1}
        return TrainState(state.step + 1, new_params, new_opt), metrics

    return step


def init_state(params, optimizer) -> TrainState:
    return TrainState(jnp.zeros((), jnp.int32), params,
                      optimizer.init(params))
