"""Optimizers (no external deps): AdamW, Adafactor, schedules, clipping.

Adafactor (factored second moment) is the memory-feasible choice for the
400B-class configs (llama4-maverick on 256 chips cannot hold AdamW's 2×fp32
state); the config's ``optimizer`` field selects per-arch.

Gradient compression: ``grad_dtype="bfloat16"`` casts params to bf16 for the
forward/backward, so the DP/FSDP reduce-scatter moves half the bytes (the
TPU-native form of gradient compression), while fp32 master params in the
optimizer state preserve convergence (error is bounded by bf16 rounding; the
master copy is the error-feedback accumulator).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------
# schedules
# --------------------------------------------------------------------------

def cosine_schedule(base_lr: float, warmup: int, total: int,
                    final_frac: float = 0.1) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * jnp.minimum(1.0, step / jnp.maximum(warmup, 1))
        t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, base_lr * cos)
    return lr


# --------------------------------------------------------------------------
# gradient utilities
# --------------------------------------------------------------------------

def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda x: (x * scale).astype(x.dtype), tree), norm


# --------------------------------------------------------------------------
# AdamW
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class AdamW:
    """AdamW with optional fp32 **master copy** for low-precision live
    params: with ``master=True`` the live params may be bf16 (so FSDP
    all-gathers move half the bytes — real gradient/weight "compression" on
    the wire) while the update happens against the fp32 master, which also
    serves as the error-feedback accumulator."""

    lr: Callable
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    master: bool = False

    def init(self, params):
        z = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        st = {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params),
              "count": jnp.zeros((), jnp.int32)}
        if self.master:
            st["master"] = jax.tree.map(
                lambda p: p.astype(jnp.float32), params)
        return st

    def update(self, grads, state, params):
        c = state["count"] + 1
        b1, b2 = self.b1, self.b2
        m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) *
                         jnp.square(g.astype(jnp.float32)), state["v"], grads)
        lr = self.lr(c)
        bc1 = 1 - b1 ** c.astype(jnp.float32)
        bc2 = 1 - b2 ** c.astype(jnp.float32)
        base = state.get("master", params)

        def upd(p32, p, m_, v_):
            step = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + self.eps)
            step = step + self.weight_decay * p32.astype(jnp.float32)
            return p32.astype(jnp.float32) - lr * step

        new_base = jax.tree.map(upd, base, params, m, v)
        new_params = jax.tree.map(lambda nb, p: nb.astype(p.dtype),
                                  new_base, params)
        out = {"m": m, "v": v, "count": c}
        if self.master:
            out["master"] = new_base
        return new_params, out


# --------------------------------------------------------------------------
# Adafactor (factored second moment, no momentum)
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class Adafactor:
    lr: Callable
    decay: float = 0.8
    eps: float = 1e-30
    clip_threshold: float = 1.0
    weight_decay: float = 0.0
    master: bool = False      # fp32 master copy for bf16 live params

    def _factored(self, shape):
        return len(shape) >= 2

    def init(self, params):
        def one(p):
            slot = ({"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                     "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                     jnp.float32)}
                    if self._factored(p.shape)
                    else {"v": jnp.zeros_like(p, dtype=jnp.float32)})
            if self.master:
                slot["master"] = p.astype(jnp.float32)
            return slot
        return {"slots": jax.tree.map(one, params,
                                      is_leaf=lambda x: hasattr(x, "shape")),
                "count": jnp.zeros((), jnp.int32)}

    def update(self, grads, state, params):
        c = state["count"] + 1
        rho = 1.0 - c.astype(jnp.float32) ** -self.decay
        lr = self.lr(c)

        def upd(p, g, slot):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + self.eps
            if self._factored(p.shape):
                vr = rho * slot["vr"] + (1 - rho) * jnp.mean(g2, axis=-1)
                vc = rho * slot["vc"] + (1 - rho) * jnp.mean(g2, axis=-2)
                denom = jnp.sqrt(
                    vr[..., None] * vc[..., None, :] /
                    jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True)[..., None],
                                self.eps))
                new_slot = {"vr": vr, "vc": vc}
            else:
                v = rho * slot["v"] + (1 - rho) * g2
                denom = jnp.sqrt(v)
                new_slot = {"v": v}
            step = g32 / jnp.maximum(denom, self.eps)
            rms = jnp.sqrt(jnp.mean(jnp.square(step)) + 1e-12)
            step = step / jnp.maximum(1.0, rms / self.clip_threshold)
            base = slot.get("master", p).astype(jnp.float32)
            if self.weight_decay:
                step = step + self.weight_decay * base
            new_base = base - lr * step
            if self.master:
                new_slot["master"] = new_base
            return new_base.astype(p.dtype), new_slot

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_s = tdef.flatten_up_to(state["slots"])
        outs = [upd(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
        new_params = tdef.unflatten([o[0] for o in outs])
        new_slots = tdef.unflatten([o[1] for o in outs])
        return new_params, {"slots": new_slots, "count": c}


def make_optimizer(name: str, lr_fn: Callable, **kw):
    if name == "adamw":
        return AdamW(lr=lr_fn, **kw)
    if name == "adafactor":
        return Adafactor(lr=lr_fn, **kw)
    raise ValueError(f"unknown optimizer {name!r}")
