"""Fault tolerance: resumable loop, step watchdog, straggler log, elastic
re-mesh.

The contract: the training loop is a pure function of (checkpoint, data
seed), so any failure mode — process crash, node loss, preemption — reduces
to "restart from the latest checkpoint", and the deterministic pipeline
(data/pipeline.py) replays the exact stream.  The watchdog flags steps whose
wall time exceeds ``straggler_factor`` × the running median (the classic
straggler signal on real pods; on multi-host it would be fed by per-host
heartbeats) and can trigger a checkpoint so a kill/reschedule loses nothing.
"""
from __future__ import annotations

import json
import os
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Optional


@dataclass
class Watchdog:
    straggler_factor: float = 3.0
    window: int = 32
    _times: deque = field(default_factory=lambda: deque(maxlen=128))
    events: list = field(default_factory=list)

    def observe(self, step: int, seconds: float) -> bool:
        """Returns True if this step is a straggler."""
        med = self.median()
        self._times.append(seconds)
        if med is not None and seconds > self.straggler_factor * med:
            self.events.append({"step": step, "seconds": seconds,
                                "median": med})
            return True
        return False

    def median(self) -> Optional[float]:
        if len(self._times) < 5:
            return None
        s = sorted(self._times)
        return s[len(s) // 2]


@dataclass
class FailureInjector:
    """Deterministic failure injection for resilience tests: raises at the
    configured steps (once each)."""

    fail_at: tuple = ()
    _fired: set = field(default_factory=set)

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self._fired:
            self._fired.add(step)
            raise RuntimeError(f"injected failure at step {step}")


def run_resumable(total_steps: int, *, make_loop: Callable,
                  ckpt_dir: str, max_restarts: int = 5) -> dict:
    """Supervisor: (re)starts the loop from the latest checkpoint until the
    step budget is done.  ``make_loop(start_step) -> (steps_done, info)``
    must checkpoint internally; on exception we restart from the last
    checkpoint (the node-failure path on a real cluster)."""
    from .checkpoint import latest_checkpoint, checkpoint_step

    restarts = 0
    history = []
    while True:
        latest = latest_checkpoint(ckpt_dir)
        start = (checkpoint_step(latest) if latest else 0)
        if start >= total_steps:
            return {"restarts": restarts, "history": history,
                    "final_step": start}
        try:
            done, info = make_loop(start)
            history.append({"start": start, "done": done, "info": info})
            if done >= total_steps:
                return {"restarts": restarts, "history": history,
                        "final_step": done}
        except RuntimeError as e:  # injected / real failure
            restarts += 1
            history.append({"start": start, "error": str(e)})
            if restarts > max_restarts:
                raise
