"""Sharded, atomic, reshard-on-restore checkpointing.

Design for 1000+ nodes:
  * each *host* writes only its addressable shards (here: one host, but the
    layout is per-shard files keyed by flattened-leaf path + shard index);
  * a manifest (JSON) records step, leaf paths, shapes, dtypes and the mesh
    the checkpoint was taken on;
  * writes go to ``<dir>/tmp.<step>`` then atomically rename to
    ``<dir>/step_<k>`` — a crash mid-write never corrupts the latest
    checkpoint;
  * restore accepts a *different* mesh: leaves are loaded whole and
    re-sharded by ``jax.device_put`` against the new sharding — elastic
    shrink/grow after node failure;
  * retention keeps the last N checkpoints.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

_SAFE = re.compile(r"[^A-Za-z0-9_.-]")


def _leaf_path(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return _SAFE.sub("_", ".".join(parts))


def save_checkpoint(ckpt_dir: str, step: int, state: Any, *,
                    keep: int = 3) -> str:
    """Write state pytree; atomic rename; enforce retention."""
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f"tmp.{step}.{os.getpid()}")
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves = jax.tree_util.tree_flatten_with_path(state)[0]
    manifest = {"step": int(step), "time": time.time(), "leaves": {}}
    for path, leaf in leaves:
        name = _leaf_path(path)
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, name + ".npy"), arr)
        manifest["leaves"][name] = {
            "shape": list(arr.shape), "dtype": str(arr.dtype)}
    with open(os.path.join(tmp, "manifest.json"), "w") as fh:
        json.dump(manifest, fh)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)

    # retention
    ckpts = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for old in ckpts[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, old))
    return final


def latest_checkpoint(ckpt_dir: str) -> Optional[str]:
    if not os.path.isdir(ckpt_dir):
        return None
    ckpts = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    return os.path.join(ckpt_dir, ckpts[-1]) if ckpts else None


def restore_checkpoint(path: str, template: Any, *,
                       shardings: Any = None) -> Any:
    """Restore into the structure of ``template``.  ``shardings`` (a pytree
    of NamedSharding or None) re-shards every leaf onto the *current* mesh —
    which may differ from the checkpoint's mesh (elastic restore)."""
    with open(os.path.join(path, "manifest.json")) as fh:
        manifest = json.load(fh)
    leaves_t = jax.tree_util.tree_flatten_with_path(template)
    flat_shard = (jax.tree_util.tree_flatten(shardings)[0]
                  if shardings is not None else [None] * len(leaves_t[0]))
    out = []
    for (pth, leaf), shd in zip(leaves_t[0], flat_shard):
        name = _leaf_path(pth)
        if name not in manifest["leaves"]:
            raise KeyError(f"checkpoint missing leaf {name!r}")
        arr = np.load(os.path.join(path, name + ".npy"))
        want_dtype = leaf.dtype if hasattr(leaf, "dtype") else arr.dtype
        arr = arr.astype(want_dtype)
        if shd is not None:
            out.append(jax.device_put(arr, shd))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(leaves_t[1], out)


def checkpoint_step(path: str) -> int:
    with open(os.path.join(path, "manifest.json")) as fh:
        return int(json.load(fh)["step"])
