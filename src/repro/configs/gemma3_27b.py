"""gemma3-27b [dense] — 62L d_model=5376 32H (GQA kv=16) d_ff=21504
vocab=262144; 5:1 local:global sliding-window attention, 128k context.
[hf:google/gemma-3-1b-pt; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b", family="dense", n_layers=62, d_model=5376,
    heads=32, kv_heads=16, head_dim=128, d_ff=21504, vocab=262144,
    qk_norm=True, rope_theta=1e6, act="gelu", gated=True,
    local_ratio=5, window=1024, embed_scale=True, tied_embeddings=True,
)

SMOKE = CONFIG.replace(
    name="gemma3-27b-smoke", n_layers=6, d_model=64, heads=4, kv_heads=2,
    head_dim=16, d_ff=128, vocab=512, window=16,
)
