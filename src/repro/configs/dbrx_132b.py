"""dbrx-132b [moe] — 40L d_model=6144 48H (GQA kv=8) d_ff=10752
vocab=100352, MoE 16 experts top-4 (fine-grained).
[hf:databricks/dbrx-base; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b", family="moe", n_layers=40, d_model=6144,
    heads=48, kv_heads=8, head_dim=128, d_ff=10752, vocab=100352,
    experts=16, top_k=4, moe_every=1,
    act="silu", gated=True, tied_embeddings=False,
)

SMOKE = CONFIG.replace(
    name="dbrx-132b-smoke", n_layers=2, d_model=64, heads=4, kv_heads=2,
    head_dim=16, d_ff=128, vocab=512, experts=4, top_k=2,
)
