"""llava-next-34b [vlm] — 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000 transformer backbone; anyres-tiled vision frontend is a STUB
(``input_specs`` supplies precomputed patch embeddings as a 576-token
prefix). [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b", family="vlm", n_layers=60, d_model=7168,
    heads=56, kv_heads=8, head_dim=128, d_ff=20480, vocab=64000,
    frontend="vision_stub", frontend_tokens=576,
    act="silu", gated=True, tied_embeddings=False,
)

SMOKE = CONFIG.replace(
    name="llava-next-34b-smoke", n_layers=2, d_model=64, heads=4, kv_heads=2,
    head_dim=16, d_ff=128, vocab=512, frontend_tokens=8,
)
