"""llama4-maverick-400b-a17b [moe] — 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, MoE 128 experts top-1, interleaved (every other
layer MoE, matching the 400B total / 17B active budget); early fusion.
Adafactor optimizer (400B × AdamW states does not fit 256 v5e chips).
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe", n_layers=48,
    d_model=5120, heads=40, kv_heads=8, head_dim=128, d_ff=8192,
    vocab=202048, experts=128, top_k=1, moe_every=2,
    act="silu", gated=True, tied_embeddings=True, optimizer="adafactor",
)

SMOKE = CONFIG.replace(
    name="llama4-maverick-smoke", n_layers=2, d_model=64, heads=4,
    kv_heads=2, head_dim=16, d_ff=128, vocab=512, experts=4, top_k=1,
    moe_every=2,
)
