"""Config system: model configs, input-shape configs, registry.

Every assigned architecture ships one ``configs/<id>.py`` exporting CONFIG
(the exact published geometry) and SMOKE (a reduced same-family config for
CPU smoke tests).  ``--arch <id>`` resolves through :func:`get_config`.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Optional

ARCH_IDS = (
    "qwen3-0.6b", "stablelm-12b", "gemma3-27b", "deepseek-7b", "rwkv6-3b",
    "llama4-maverick-400b-a17b", "dbrx-132b", "zamba2-7b", "llava-next-34b",
    "seamless-m4t-medium",
)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | rwkv | hybrid | vlm | encdec
    n_layers: int
    d_model: int
    vocab: int
    heads: int = 0
    kv_heads: int = 0
    head_dim: int = 0           # 0 -> d_model // heads
    d_ff: int = 0
    qk_norm: bool = False
    rope_theta: float = 10000.0
    act: str = "silu"
    gated: bool = True
    # local:global attention (gemma3-style)
    local_ratio: int = 0        # N local layers per 1 global; 0 = all global
    window: int = 0
    # MoE
    experts: int = 0
    top_k: int = 0
    moe_every: int = 1          # MoE layer cadence (1 = every layer)
    pin_moe_layout: bool = False  # explicit a2a-boundary constraints (needed
                                  # only when weights replicate over data)
    # SSM / hybrid
    ssm_state: int = 0
    expand: int = 2
    mamba_head_dim: int = 64
    shared_attn_period: int = 0  # zamba2: shared attn block every k blocks
    # enc-dec
    enc_layers: int = 0
    dec_layers: int = 0
    # multimodal frontend stub
    frontend: str = "none"      # none | vision_stub | audio_stub
    frontend_tokens: int = 0    # prefix length supplied as embeddings
    # numerics / training
    tied_embeddings: bool = True
    embed_scale: bool = False
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    optimizer: str = "adamw"
    remat: str = "full"         # full | dots | dots_no_batch | none
    scan_unroll: int = 1

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.heads, 1))

    @property
    def padded_vocab(self) -> int:
        """Embedding rows padded to a multiple of 256 so the vocab dim always
        shards evenly over a 16-way model axis (and 16-way data FSDP).
        Logits in the padding region are masked to −inf before the loss."""
        return -(-self.vocab // 256) * 256

    @property
    def attention_free(self) -> bool:
        return self.family == "rwkv"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (see DESIGN.md §long_500k skips)."""
        return (self.family in ("rwkv", "hybrid")
                or (self.local_ratio > 0 and self.window > 0))

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6·N·D)."""
        e, f, v = self.d_model, self.d_ff, self.vocab
        h, k, d = self.heads, self.kv_heads, self.resolved_head_dim
        attn = e * (h + 2 * k) * d + h * d * e
        mlp = e * f * (3 if self.gated else 2)
        emb = v * e * (1 if self.tied_embeddings else 2)
        if self.family == "rwkv":
            tm = 5 * e * e + 2 * e * 64 + 2 * e
            cm = 2 * e * f + e * e
            return self.n_layers * (tm + cm) + emb
        if self.family == "hybrid":
            ei = self.expand * e
            blk = e * (2 * ei + 2 * self.ssm_state +
                       ei // self.mamba_head_dim) + ei * e
            shared = attn + mlp
            return self.n_layers * blk + shared + emb
        if self.family == "moe":
            moe = e * self.experts + self.experts * 3 * e * f
            n_moe = self.n_layers // self.moe_every
            n_dense = self.n_layers - n_moe
            return (self.n_layers * attn + n_moe * moe + n_dense * mlp + emb)
        layers = self.enc_layers + self.dec_layers or self.n_layers
        xattn = attn if self.family == "encdec" else 0
        return layers * (attn + mlp) + (self.dec_layers or 0) * xattn + emb

    def active_param_count(self) -> int:
        if self.family != "moe":
            return self.param_count()
        e, f = self.d_model, self.d_ff
        h, k, d = self.heads, self.kv_heads, self.resolved_head_dim
        attn = e * (h + 2 * k) * d + h * d * e
        act_moe = e * self.experts + self.top_k * 3 * e * f
        emb = self.vocab * e
        return self.n_layers * (attn + act_moe) + emb


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(
        f"repro.configs.{arch.replace('-', '_').replace('.', '_')}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(
        f"repro.configs.{arch.replace('-', '_').replace('.', '_')}")
    return mod.SMOKE


def shape_cells(cfg: ModelConfig):
    """The (arch × shape) cells this arch runs (long_500k gated on
    sub-quadratic support; see DESIGN.md)."""
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        cells.append("long_500k")
    return [SHAPES[c] for c in cells]
