"""deepseek-7b [dense] — 30L d_model=4096 32H (GQA kv=32 = MHA) d_ff=11008
vocab=102400; llama-arch. [arXiv:2401.02954; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b", family="dense", n_layers=30, d_model=4096,
    heads=32, kv_heads=32, head_dim=128, d_ff=11008, vocab=102400,
    act="silu", gated=True, tied_embeddings=False,
)

SMOKE = CONFIG.replace(
    name="deepseek-7b-smoke", n_layers=2, d_model=64, heads=4, kv_heads=4,
    head_dim=16, d_ff=128, vocab=512,
)
