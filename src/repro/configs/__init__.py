from .base import (ARCH_IDS, SHAPES, ModelConfig, ShapeConfig, get_config,
                   get_smoke_config, shape_cells)

__all__ = ["ARCH_IDS", "SHAPES", "ModelConfig", "ShapeConfig", "get_config",
           "get_smoke_config", "shape_cells"]
