"""zamba2-7b [hybrid] — 81 Mamba2 blocks, d_model=3584, ssm_state=64, with a
weight-shared attention block (32H GQA kv=32, d_ff=14336 MLP) applied every
6 blocks; vocab=32000. [arXiv:2411.15242; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid", n_layers=81, d_model=3584,
    heads=32, kv_heads=32, head_dim=112, d_ff=14336, vocab=32000,
    ssm_state=64, expand=2, mamba_head_dim=64, shared_attn_period=6,
    act="gelu", gated=True, tied_embeddings=True,
)

SMOKE = CONFIG.replace(
    name="zamba2-7b-smoke", n_layers=4, d_model=64, heads=4, kv_heads=4,
    head_dim=16, d_ff=128, vocab=512, ssm_state=8, mamba_head_dim=16,
    shared_attn_period=2,
)
