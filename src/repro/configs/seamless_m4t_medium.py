"""seamless-m4t-medium [audio] — enc-dec transformer backbone: 12L encoder +
12L decoder, d_model=1024 16H (GQA kv=16) d_ff=4096 vocab=256206; the speech
frontend is a STUB (``input_specs`` supplies precomputed frame embeddings to
the encoder). [arXiv:2308.11596; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="encdec", n_layers=24,
    enc_layers=12, dec_layers=12, d_model=1024, heads=16, kv_heads=16,
    head_dim=64, d_ff=4096, vocab=256206, frontend="audio_stub",
    act="relu", gated=False, tied_embeddings=True,
)

SMOKE = CONFIG.replace(
    name="seamless-m4t-smoke", n_layers=4, enc_layers=2, dec_layers=2,
    d_model=64, heads=4, kv_heads=4, head_dim=16, d_ff=128, vocab=512,
)
