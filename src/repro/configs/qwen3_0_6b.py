"""qwen3-0.6b [dense] — 28L d_model=1024 16H (GQA kv=8) d_ff=3072
vocab=151936; qk_norm, head_dim=128. [hf:Qwen/Qwen3-8B; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b", family="dense", n_layers=28, d_model=1024,
    heads=16, kv_heads=8, head_dim=128, d_ff=3072, vocab=151936,
    qk_norm=True, rope_theta=1e6, act="silu", gated=True,
    tied_embeddings=True,
)

SMOKE = CONFIG.replace(
    name="qwen3-0.6b-smoke", n_layers=2, d_model=64, heads=4, kv_heads=2,
    head_dim=16, d_ff=128, vocab=512,
)
