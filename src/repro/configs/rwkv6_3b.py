"""rwkv6-3b [ssm] — Finch: 32L d_model=2560 (attention-free) d_ff=8960
vocab=65536; data-dependent decay, head_dim=64 ⇒ 40 heads.
[arXiv:2404.05892; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="rwkv", n_layers=32, d_model=2560,
    heads=40, kv_heads=40, head_dim=64, d_ff=8960, vocab=65536,
    act="relu2", gated=False, tied_embeddings=False,
)

SMOKE = CONFIG.replace(
    name="rwkv6-3b-smoke", n_layers=2, d_model=64, heads=4, kv_heads=4,
    head_dim=16, d_ff=128, vocab=512,
)
