"""stablelm-12b [dense] — 40L d_model=5120 32H (GQA kv=8) d_ff=13824
vocab=100352. [hf:stabilityai/stablelm-2-1_6b; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b", family="dense", n_layers=40, d_model=5120,
    heads=32, kv_heads=8, head_dim=160, d_ff=13824, vocab=100352,
    act="silu", gated=True, tied_embeddings=False,
)

SMOKE = CONFIG.replace(
    name="stablelm-12b-smoke", n_layers=2, d_model=64, heads=4, kv_heads=2,
    head_dim=16, d_ff=128, vocab=512,
)
