"""Serving driver: batched prefill + decode with KV caches.

CPU-scale demo:
  python -m repro.launch.serve --arch gemma3-27b --smoke --batch 2 \
      --prompt-len 12 --gen 20 --ring-local
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, get_smoke_config
from ..core.executor import plan_and_compile
from ..core.ir import SystemCatalog
from ..models import build_model
from ..models.decode import decode_step, init_cache
from ..models.lm import CATALOG


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--ring-local", action="store_true",
                    help="ring-buffer caches for sliding-window layers")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch))
    if args.smoke:
        cfg = cfg.replace(dtype="float32")
    model = build_model(cfg)
    params, _ = model.init_params(jax.random.key(args.seed))
    rng = np.random.RandomState(args.seed)
    b = args.batch
    max_seq = args.prompt_len + args.gen

    prompts = jnp.asarray(rng.randint(0, cfg.vocab, (b, args.prompt_len)),
                          jnp.int32)
    cache = init_cache(model, b, max_seq, ring_local=args.ring_local)
    dstep = jax.jit(lambda p, c, t, i: decode_step(
        model, p, c, t, i, ring_local=args.ring_local))

    # prefill token-by-token through the cached path (throughput prefill is
    # the planner-compiled forward; see launch/dryrun.py prefill cells)
    t0 = time.time()
    logits = None
    for t in range(args.prompt_len):
        logits, cache = dstep(params, cache, prompts[:, t:t + 1],
                              jnp.int32(t))
    t_prefill = time.time() - t0

    out_tokens = []
    tok = jnp.argmax(logits[:, :, :cfg.vocab], axis=-1).astype(jnp.int32)
    t0 = time.time()
    for t in range(args.prompt_len, max_seq):
        out_tokens.append(np.asarray(tok)[:, 0])
        logits, cache = dstep(params, cache, tok, jnp.int32(t))
        tok = jnp.argmax(logits[:, :, :cfg.vocab], axis=-1).astype(jnp.int32)
    t_gen = time.time() - t0

    gen = np.stack(out_tokens, axis=1)
    print(f"[serve] arch={cfg.name} batch={b} prompt={args.prompt_len} "
          f"gen={args.gen}")
    print(f"[serve] prefill {t_prefill * 1e3:.0f} ms; "
          f"decode {t_gen / max(args.gen, 1) * 1e3:.1f} ms/token")
    print(f"[serve] sample generations (token ids): {gen[:, :8].tolist()}")
    return gen


if __name__ == "__main__":
    main()
