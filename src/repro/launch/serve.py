"""Serving driver: batched prefill + decode with KV caches.

The prompt's logits come from the planner-compiled forward (the throughput
prefill path — same plan the dry-run's prefill cells lower), compiled
through the content-hashed **plan cache** with prompt lengths bucketed to
powers of two: across requests, every bucket is planned once and every
subsequent request in that bucket is a cache hit instead of a replan.

CPU-scale demo:
  python -m repro.launch.serve --arch gemma3-27b --smoke --batch 2 \
      --prompt-len 12 --gen 20 --ring-local --requests 3
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, get_smoke_config
from ..core.executor import plan_and_compile
from ..core.ir import SystemCatalog
from ..core.plan_cache import default_plan_cache
from ..models import build_model
from ..models.decode import decode_step, init_cache
from ..models.lm import CATALOG


def bucket_len(n: int, lo: int = 8) -> int:
    """Round a prompt length up to the next power-of-two bucket, so repeated
    traffic with varying lengths maps onto a handful of cached plans."""
    b = lo
    while b < n:
        b *= 2
    return b


def planned_prefill(model, syscat, batch: int, prompt_len: int):
    """Compile (or fetch from the plan cache) the prefill forward for this
    request's bucket.  Returns (planned_fn, bucket)."""
    bucket = bucket_len(prompt_len)
    plan = model.build_plan(batch, bucket, mode="prefill")
    fwd = plan_and_compile(plan, CATALOG, syscat, engines=("xla",))
    return fwd, bucket


def serve_request(model, cfg, params, dstep, fwd, bucket, prompts, gen: int,
                  *, ring_local: bool = False):
    """One request: planned prefill for the prompt logits, then cached
    token-by-token decode for generation."""
    b, prompt_len = prompts.shape
    max_seq = prompt_len + gen

    # throughput prefill: one planned forward over the (bucketed) prompt.
    # right-padding is sound under causal attention — positions before
    # prompt_len never attend to the padding.
    t0 = time.time()
    padded = jnp.zeros((b, bucket), jnp.int32).at[:, :prompt_len].set(prompts)
    logits_all = fwd(params, {"tokens": padded})
    tok = jnp.argmax(logits_all[:, prompt_len - 1, :cfg.vocab],
                     axis=-1).astype(jnp.int32)[:, None]

    # fill the KV cache along the cached decode path (the ROADMAP item to
    # lift K/V out of the planned forward would drop this replay); counted
    # inside t_prefill — it is real per-request prompt cost
    cache = init_cache(model, b, max_seq, ring_local=ring_local)
    for t in range(prompt_len):
        _, cache = dstep(params, cache, prompts[:, t:t + 1], jnp.int32(t))
    t_prefill = time.time() - t0

    out_tokens = []
    t0 = time.time()
    for t in range(prompt_len, max_seq):
        out_tokens.append(np.asarray(tok)[:, 0])
        logits, cache = dstep(params, cache, tok, jnp.int32(t))
        tok = jnp.argmax(logits[:, :, :cfg.vocab], axis=-1).astype(jnp.int32)
    t_gen = time.time() - t0
    return np.stack(out_tokens, axis=1), t_prefill, t_gen


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--requests", type=int, default=1,
                    help="number of sequential requests to serve; requests "
                         "after the first hit the plan cache")
    ap.add_argument("--ring-local", action="store_true",
                    help="ring-buffer caches for sliding-window layers")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch))
    if args.smoke:
        cfg = cfg.replace(dtype="float32")
    model = build_model(cfg)
    syscat = SystemCatalog()
    params, _ = model.init_params(jax.random.key(args.seed))
    rng = np.random.RandomState(args.seed)
    b = args.batch

    dstep = jax.jit(lambda p, c, t, i: decode_step(
        model, p, c, t, i, ring_local=args.ring_local))

    pc = default_plan_cache()
    gen = None
    for r in range(args.requests):
        prompts = jnp.asarray(
            rng.randint(0, cfg.vocab, (b, args.prompt_len)), jnp.int32)
        t0 = time.time()
        fwd, bucket = planned_prefill(model, syscat, b, args.prompt_len)
        t_plan = time.time() - t0
        gen, t_prefill, t_gen = serve_request(
            model, cfg, params, dstep, fwd, bucket, prompts, args.gen,
            ring_local=args.ring_local)
        print(f"[serve] req {r}: plan {t_plan * 1e3:.1f} ms "
              f"(bucket {bucket}, plan {fwd.plan_id[:12]}); "
              f"prefill {t_prefill * 1e3:.0f} ms; "
              f"decode {t_gen / max(args.gen, 1) * 1e3:.1f} ms/token")

    s = pc.stats()
    print(f"[serve] arch={cfg.name} batch={b} prompt={args.prompt_len} "
          f"gen={args.gen} requests={args.requests}")
    print(f"[serve] plan cache: {s['hits']} hits / {s['misses']} misses "
          f"(hit rate {s['hit_rate']:.2f})")
    print(f"[serve] sample generations (token ids): {gen[:, :8].tolist()}")
    return gen


if __name__ == "__main__":
    main()
