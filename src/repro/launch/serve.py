"""Serving driver: a thin CLI over the async serving runtime.

Requests (mixed prompt lengths) are admitted by power-of-two bucket so every
warm bucket hits an already-cached StagedPhysicalPlan, prefilled through the
planned ``prefill_kv`` forward (per-layer K/V are plan outputs that seed the
paged KV pool directly — no prompt replay), and decoded with continuous
batching: requests join/leave the fixed-width decode batch at token
boundaries.

CPU-scale demo:
  python -m repro.launch.serve --arch qwen3-0.6b --smoke --requests 8 \
      --gen 16 --max-batch 4

``serve_request`` / ``planned_prefill`` are the seed's sequential-path
helpers, kept as compatibility wrappers (and as the benchmark baseline).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, get_smoke_config
from ..core.executor import plan_and_compile
from ..core.ir import SystemCatalog
from ..models import build_model
from ..models.decode import decode_step, init_cache
from ..models.lm import CATALOG
from ..serving import AsyncServingRuntime, ServeRequest
from ..serving.admission import bucket_len  # compat re-export  # noqa: F401


def planned_prefill(model, syscat, batch: int, prompt_len: int,
                    cache=None, engines=("xla",)):
    """Compile (or fetch from the plan cache) the prefill forward for this
    request's bucket.  Returns (planned_fn, bucket).  (Seed-path compat.)"""
    bucket = bucket_len(prompt_len)
    plan = model.build_plan(batch, bucket, mode="prefill")
    fwd = plan_and_compile(plan, CATALOG, syscat, engines=engines,
                           cache=cache)
    return fwd, bucket


def serve_request(model, cfg, params, dstep, fwd, bucket, prompts, gen: int,
                  *, ring_local: bool = False):
    """One sequential request: planned prefill for the prompt logits, then
    cached token-by-token decode.  (Seed-path compat; the async runtime's
    ``prefill_kv`` path replaces the KV-rebuild replay below.)"""
    b, prompt_len = prompts.shape
    max_seq = prompt_len + gen

    t0 = time.time()
    padded_np = np.zeros((b, bucket), np.int32)
    padded_np[:, :prompt_len] = np.asarray(prompts)
    logits_all = fwd(params, {"tokens": jnp.asarray(padded_np)})
    tok = jnp.argmax(logits_all[:, prompt_len - 1, :cfg.vocab],
                     axis=-1).astype(jnp.int32)[:, None]

    # the replay path: rebuild the KV cache through cached decode — the
    # sequential baseline the async runtime's plan-output seeding removes
    cache = init_cache(model, b, max_seq, ring_local=ring_local)
    for t in range(prompt_len):
        _, cache = dstep(params, cache, prompts[:, t:t + 1], jnp.int32(t))
    t_prefill = time.time() - t0

    out_tokens = []
    t0 = time.time()
    for t in range(prompt_len, max_seq):
        out_tokens.append(np.asarray(tok)[:, 0])
        logits, cache = dstep(params, cache, tok, jnp.int32(t))
        tok = jnp.argmax(logits[:, :, :cfg.vocab], axis=-1).astype(jnp.int32)
    t_gen = time.time() - t0
    return np.stack(out_tokens, axis=1), t_prefill, t_gen


def make_trace(rng, cfg, n_requests: int, prompt_lens, gen: int,
               arrival_spacing: float = 0.0) -> list:
    """A mixed-length request trace (round-robin over ``prompt_lens``)."""
    reqs = []
    for i in range(n_requests):
        n = prompt_lens[i % len(prompt_lens)]
        reqs.append(ServeRequest(
            i, tuple(rng.randint(0, cfg.vocab, n).tolist()), gen,
            arrival=i * arrival_spacing))
    return reqs


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-lens", default="5,12,8,20,16,3,27,9",
                    help="comma-separated prompt lengths, cycled over "
                         "requests (mixed lengths exercise the buckets)")
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4,
                    help="decode-batch width (continuous batching slots)")
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--page-size", type=int, default=16,
                    help="KV-pool page size (tokens)")
    ap.add_argument("--arrival-spacing", type=float, default=0.0,
                    help="seconds between request arrivals")
    ap.add_argument("--engines", default="xla")
    ap.add_argument("--plan-cache-dir", default=None,
                    help="persist/warm-start the plan cache here")
    ap.add_argument("--explain", action="store_true",
                    help="print one bucket's EXPLAIN report")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch))
    if args.smoke:
        cfg = cfg.replace(dtype="float32")
    model = build_model(cfg)
    params, _ = model.init_params(jax.random.key(args.seed))
    rng = np.random.RandomState(args.seed)
    prompt_lens = [int(x) for x in args.prompt_lens.split(",")]

    rt = AsyncServingRuntime(
        model, params, max_batch=args.max_batch, max_seq=args.max_seq,
        page_size=args.page_size, engines=tuple(args.engines.split(",")),
        plan_cache_dir=args.plan_cache_dir)
    print(f"[serve] arch={cfg.name} mode="
          f"{'prefill_kv (plan-seeded KV)' if rt.kv_mode else 'replay'} "
          f"max_batch={args.max_batch} max_seq={args.max_seq}")

    t0 = time.time()
    rt.warmup(prompt_lens)
    print(f"[serve] warmup (plans + jit) {time.time() - t0:.2f}s; "
          f"buckets {sorted(rt._prefill_fns)}")
    if args.explain:
        fwd, _ = rt._prefill_fns[sorted(rt._prefill_fns)[0]]
        print(fwd.explain())

    reqs = make_trace(rng, cfg, args.requests, prompt_lens, args.gen,
                      args.arrival_spacing)
    t0 = time.time()
    results = rt.serve(reqs)
    wall = time.time() - t0
    toks = sum(len(r.tokens) for r in results)
    print(rt.metrics.report())
    print(f"[serve] {toks} tokens in {wall:.2f}s -> {toks / wall:.1f} tok/s; "
          f"pool {rt.pool.occupancy()}")
    s = rt.pc.stats()
    print(f"[serve] plan cache: {s['hits']} hits / {s['misses']} misses "
          f"(hit rate {s['hit_rate']:.2f})")
    sample = [r.tokens[:8] for r in results[:2]]
    print(f"[serve] sample generations (token ids): {sample}")
    return results


if __name__ == "__main__":
    main()
