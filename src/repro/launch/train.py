"""End-to-end training driver.

CPU-scale demo:   python -m repro.launch.train --arch qwen3-0.6b --smoke \
                      --steps 50 --batch 4 --seq 64
Production shape: same flags minus --smoke, plus a real mesh (the dry-run
proves those configs compile; actually running them needs TPUs).

Fault tolerance is on by default: checkpoints every ``--ckpt-every`` steps,
resumes from the latest checkpoint, the watchdog logs stragglers, and the
deterministic pipeline replays the stream on restart.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from ..configs import SHAPES, get_config, get_smoke_config
from ..core.executor import plan_and_compile
from ..core.ir import SystemCatalog
from ..core.plan_cache import (default_plan_cache, load_plan_cache,
                               save_plan_cache)
from ..data.pipeline import DataConfig, PrefetchPipeline
from ..models import build_model
from ..models.lm import CATALOG
from ..train.checkpoint import (latest_checkpoint, restore_checkpoint,
                                save_checkpoint, checkpoint_step)
from ..train.fault_tolerance import Watchdog
from ..train.optim import cosine_schedule, make_optimizer
from ..train.train_step import init_state, make_train_step
from .mesh import syscat_for_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--buffering", action="store_true")
    ap.add_argument("--engines", default="xla",
                    help="comma-separated engine names the planner may use "
                         "(registry: xla, pallas)")
    ap.add_argument("--plan-cache-dir", default=None,
                    help="persist the plan cache here and warm-start "
                         "planning from it on relaunch")
    ap.add_argument("--plan-threads", type=int, default=1,
                    help="generate physical candidates per scan-group in "
                         "this many threads (identical plans, lower "
                         "planning wall time)")
    ap.add_argument("--explain", action="store_true",
                    help="print the staged plan pipeline's EXPLAIN report")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch))
    if args.smoke:
        cfg = cfg.replace(dtype="float32")
    model = build_model(cfg)
    syscat = SystemCatalog()

    plan = model.build_plan(args.batch, args.seq, mode="train")
    # planned through the content-hashed plan cache: re-launching the same
    # workload (or rebuilding the step in-process) reuses the staged plan;
    # with --plan-cache-dir the cache warm-starts across process restarts
    pc = default_plan_cache()
    if args.plan_cache_dir:
        load_plan_cache(args.plan_cache_dir, pc)
    fwd = plan_and_compile(plan, CATALOG, syscat, buffering=args.buffering,
                           global_batch=args.batch,
                           engines=tuple(args.engines.split(",")),
                           plan_threads=args.plan_threads)
    if args.plan_cache_dir:
        n = save_plan_cache(pc, args.plan_cache_dir)
        print(f"[train] plan cache: {pc.stats()['hits']} hits, "
              f"persisted {n} new staged plan(s) to {args.plan_cache_dir}")
    print(f"[train] plan {fwd.plan_id[:12]} choices: "
          f"{[(r['pattern'], r['chosen']) for r in fwd.report]}")
    if args.explain:
        print(fwd.explain())
    if fwd.buffering.enabled:
        print(f"[train] buffering: {fwd.buffering.num_microbatches} "
              f"microbatches over {len(fwd.buffering.chains)} chains")

    opt = make_optimizer(cfg.optimizer, cosine_schedule(
        args.lr, max(args.steps // 20, 1), args.steps))
    nmb = (fwd.buffering.num_microbatches if fwd.buffering.enabled
           else args.microbatches)
    step = jax.jit(make_train_step(fwd, opt, num_microbatches=nmb,
                                   grad_dtype="float32"))

    params, _ = model.init_params(jax.random.key(args.seed))
    state = init_state(params, opt)

    ckpt_dir = args.ckpt_dir or f"checkpoints/{cfg.name}"
    start = 0
    latest = latest_checkpoint(ckpt_dir)
    if latest:
        state = restore_checkpoint(latest, jax.eval_shape(lambda: state))
        start = checkpoint_step(latest)
        print(f"[train] resumed from {latest} at step {start}")

    dc = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                    global_batch=args.batch, seed=args.seed,
                    frontend_tokens=cfg.frontend_tokens,
                    d_model=cfg.d_model, encdec=cfg.family == "encdec",
                    dtype=str(model.dtype))
    pipe = PrefetchPipeline(dc, start_step=start)
    wd = Watchdog()
    t_last = time.time()
    try:
        for i, (step_idx, batch) in enumerate(pipe):
            if step_idx >= args.steps:
                break
            jbatch = {k: jnp.asarray(v) for k, v in batch.items()}
            state, metrics = step(state, jbatch)
            dt = time.time() - t_last
            t_last = time.time()
            if wd.observe(step_idx, dt):
                print(f"[train] straggler step {step_idx}: {dt:.2f}s "
                      f"(median {wd.median():.2f}s) — checkpointing")
                save_checkpoint(ckpt_dir, step_idx + 1, state)
            if step_idx % args.log_every == 0:
                print(f"[train] step {step_idx:5d} "
                      f"loss {float(metrics['loss']):.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"{dt * 1e3:.0f} ms")
            if (step_idx + 1) % args.ckpt_every == 0:
                save_checkpoint(ckpt_dir, step_idx + 1, state)
    finally:
        pipe.close()
    save_checkpoint(ckpt_dir, args.steps, state)
    print(f"[train] done at step {args.steps}; "
          f"final loss {float(metrics['loss']):.4f}")
    return float(metrics["loss"])


if __name__ == "__main__":
    main()
