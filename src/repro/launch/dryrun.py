import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes and extract the roofline terms.

This is the proof that the distribution config is coherent without real
hardware: 512 host-platform placeholder devices build the (2,16,16)
pod/data/model mesh (and its (16,16) single-pod slice), every cell's
train_step / serve_step must ``.lower().compile()``, and the compiled
artifact yields ``memory_analysis()`` (fits?) + ``cost_analysis()`` (FLOPs /
bytes) + the collective schedule (parsed from the post-SPMD HLO).

Usage:
  python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out experiments/dryrun]
"""
import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from ..configs import ARCH_IDS, SHAPES, get_config, shape_cells
from ..core.executor import ShardingRules, params_sharding, plan_and_compile
from ..core.ir import SystemCatalog
from ..models import build_model
from ..models.decode import decode_step, init_cache
from ..models.lm import CATALOG
from ..train.optim import cosine_schedule, make_optimizer
from ..train.train_step import TrainState, init_state, make_train_step
from .hlo_analysis import analyze_hlo
from .mesh import (input_shardings, make_production_mesh, state_shardings,
                   syscat_for_mesh)

P = jax.sharding.PartitionSpec


# --------------------------------------------------------------------------
# per-cell lowering
# --------------------------------------------------------------------------

def _batch_axes(mesh, batch):
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp = 1
    for a in axes:
        dp *= mesh.shape[a]
    return axes if batch % dp == 0 else None


def build_train_step(arch: str, mesh, *, grad_dtype="bfloat16",
                     num_microbatches=1, remat=None, rules=None,
                     extra_cfg=None):
    cfg = get_config(arch)
    if remat is not None:
        cfg = cfg.replace(remat=remat)
    if extra_cfg:
        cfg = cfg.replace(**extra_cfg)
    model = build_model(cfg)
    shape = SHAPES["train_4k"]
    rules = rules or ShardingRules()
    syscat = syscat_for_mesh(mesh)
    plan = model.build_plan(shape.global_batch, shape.seq_len, mode="train")
    # engine selection goes through the registry; the Pallas engines are not
    # calibrated on the host-platform dry-run, so only xla is offered.  The
    # plan cache makes rebuilding the same (arch × shape × mesh) step a hit.
    fwd = plan_and_compile(plan, CATALOG, syscat, mesh=mesh, rules=rules,
                           engines=("xla",))
    opt = make_optimizer(cfg.optimizer, cosine_schedule(3e-4, 100, 10000))
    step = make_train_step(fwd, opt, num_microbatches=num_microbatches,
                           grad_dtype=grad_dtype)
    return cfg, model, opt, step, fwd


INFERENCE_RULES = ShardingRules(param=tuple(
    (d, ax) for d, ax in ShardingRules().param if d != "embed"))
# inference: no optimizer state exists, so there is no reason to FSDP the
# weights over `data` — dropping the "embed"→data rule removes the per-layer
# weight all-gathers entirely (weights live TP-sharded, replicated over data)


def lower_cell(arch: str, shape_name: str, mesh, *, opts=None):
    """Lower + compile one (arch × shape × mesh) cell; return the record."""
    opts = opts or {}
    cfg = get_config(arch)
    if opts.get("cfg_overrides"):
        cfg = cfg.replace(**opts["cfg_overrides"])
    model = build_model(cfg)
    shape = SHAPES[shape_name]
    rules = opts.get("rules") or ShardingRules()
    if opts.get("inference_rules") and shape.kind != "train":
        rules = INFERENCE_RULES
    if opts.get("no_fsdp"):
        rules = INFERENCE_RULES   # drop embed→data everywhere (ZeRO-1-ish)
    if opts.get("expert_nofsdp"):
        rules = ShardingRules(act=rules.act, param=rules.param,
                              no_fsdp_experts=True)
    syscat = syscat_for_mesh(mesh)
    t0 = time.time()

    if shape.kind in ("train", "prefill"):
        mode = "train" if shape.kind == "train" else "prefill"
        plan = model.build_plan(shape.global_batch, shape.seq_len, mode=mode)
        fwd = plan_and_compile(plan, CATALOG, syscat, mesh=mesh, rules=rules,
                               engines=("xla",))
        in_sds = model.input_specs(shape)
        in_shard = input_shardings(mesh, in_sds)
        p_abs = model.abstract_params()
        p_shard = params_sharding(model.param_specs(), mesh, rules)

        if shape.kind == "train":
            okw = {"master": True} if opts.get("master") else {}
            opt = make_optimizer(cfg.optimizer,
                                 cosine_schedule(3e-4, 100, 10000), **okw)
            step = make_train_step(
                fwd, opt, grad_dtype=opts.get("grad_dtype", "bfloat16"),
                num_microbatches=opts.get("num_microbatches", 1))
            st_shard = state_shardings(mesh, model, opt, rules)
            st_abs = jax.eval_shape(
                lambda p: TrainState(jnp.zeros((), jnp.int32), p,
                                     opt.init(p)), p_abs)
            jitted = jax.jit(step, in_shardings=(st_shard, in_shard),
                             out_shardings=(st_shard, None),
                             donate_argnums=(0,))
            lowered = jitted.lower(st_abs, in_sds)
        else:
            def prefill_fn(params, inputs):
                return fwd(params, inputs)
            jitted = jax.jit(prefill_fn, in_shardings=(p_shard, in_shard))
            lowered = jitted.lower(p_abs, in_sds)
        sel = [(r["pattern"], r["chosen"]) for r in fwd.report]
    else:  # decode
        p_abs = model.abstract_params()
        p_shard = params_sharding(model.param_specs(), mesh, rules)
        ring = opts.get("ring_local", False)
        kv_rep = opts.get("kv_repeat_tp", 0)
        cache_abs = init_cache(model, shape.global_batch, shape.seq_len,
                               ring_local=ring, abstract=True,
                               kv_repeat_to=kv_rep,
                               quantize_kv=opts.get("quantize_kv", False))
        cache_shard = cache_shardings(mesh, model, cache_abs, shape,
                                      kv_shard_seq=opts.get("kv_shard_seq",
                                                            False),
                                      kv_shard_dim=opts.get("kv_shard_dim",
                                                            False))
        in_sds = model.input_specs(shape)
        tok_shard = jax.sharding.NamedSharding(
            mesh, P(_batch_axes(mesh, shape.global_batch)))
        repl = jax.sharding.NamedSharding(mesh, P())

        def serve_step(params, cache, tokens, index):
            return decode_step(model, params, cache, tokens, index,
                               ring_local=ring)

        jitted = jax.jit(serve_step,
                         in_shardings=(p_shard, cache_shard, tok_shard, repl),
                         donate_argnums=(1,))
        lowered = jitted.lower(p_abs, cache_abs, in_sds["tokens"],
                               in_sds["index"])
        sel = []

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # older jax returns [dict]
        cost = cost[0] if cost else {}
    try:
        mem = compiled.memory_analysis()
        mem_rec = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(
                mem, "generated_code_size_in_bytes", None),
        }
    except Exception as e:  # memory analysis unsupported on this backend
        mem_rec = {"error": str(e)}

    hlo = analyze_hlo(compiled.as_text())
    n_dev = mesh.devices.size
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": {a: int(mesh.shape[a]) for a in mesh.axis_names},
        "devices": int(n_dev),
        # trip-count-corrected whole-module terms (per device)
        "flops": hlo["flops"],
        "hbm_bytes": hlo["hbm_bytes"],
        "collectives": hlo["collectives"],
        "wire_bytes": hlo["wire_bytes"],
        # raw XLA numbers (while bodies counted once) for reference
        "xla_flops_raw": cost.get("flops", 0.0),
        "xla_bytes_raw": cost.get("bytes accessed", 0.0),
        "memory": mem_rec,
        "selected": sel,
        "t_lower_s": round(t_lower, 2),
        "t_compile_s": round(t_compile, 2),
        "opts": {k: v for k, v in (opts or {}).items() if k != "rules"},
    }
    return rec


def cache_shardings(mesh, model, cache_abs, shape, *, kv_shard_seq=False,
                    kv_shard_dim=False):
    """KV caches: batch→(pod,data) when divisible, kv-heads/state→model.
    When kv heads don't divide the model axis:
      ``kv_shard_seq``: shard the cache *sequence* axis over model
      (sequence-parallel decode — GSPMD turns softmax reductions into
      collectives; measured poorly, kept as a documented refutation);
      ``kv_shard_dim``: shard *head_dim* over model — the qk contraction
      partial-sums and GSPMD all-reduces the (small) logits, while cache
      reads divide by the model axis (Megatron-style channel sharding)."""
    baxes = _batch_axes(mesh, shape.global_batch)

    model_size = mesh.shape["model"]

    def one(path, leaf):
        r = len(leaf.shape)
        key = str(path[-1].key) if path else ""
        spec = [None] * r
        if r >= 2:
            spec[1] = baxes                      # (count, B, ...)
        if key.endswith(("_k", "_v", "_xk", "_xv")) and r == 5:
            # (count, B, S, KV, D): shard kv heads when divisible
            if leaf.shape[3] % model_size == 0:
                spec[3] = "model"
            elif kv_shard_dim and leaf.shape[4] % model_size == 0:
                spec[4] = "model"                # channel-sharded cache
            elif kv_shard_seq and leaf.shape[2] % model_size == 0:
                spec[2] = "model"                # sequence-parallel cache
        elif key.endswith("_state") and r >= 4:
            # (count, B, H, N, P) / (count, B, H, D, D): shard heads
            if leaf.shape[2] % model_size == 0:
                spec[2] = "model"
        elif key.endswith("_conv") and r == 4:
            if leaf.shape[3] % model_size == 0:
                spec[3] = "model"                # channel dim
        return jax.sharding.NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, cache_abs)


# --------------------------------------------------------------------------
# driver
# --------------------------------------------------------------------------

def run_all(out_dir: str, *, multi_pod: bool, only_arch=None, only_shape=None,
            opts=None):
    os.makedirs(out_dir, exist_ok=True)
    mesh = make_production_mesh(multi_pod=multi_pod)
    tag = "multipod" if multi_pod else "singlepod"
    results = []
    for arch in ARCH_IDS:
        if only_arch and arch != only_arch:
            continue
        cfg = get_config(arch)
        for shape in shape_cells(cfg):
            if only_shape and shape.name != only_shape:
                continue
            name = f"{arch}__{shape.name}__{tag}"
            path = os.path.join(out_dir, name + ".json")
            print(f"[dryrun] {name} ...", flush=True)
            try:
                rec = lower_cell(arch, shape.name, mesh, opts=opts)
                rec["status"] = "ok"
                print(f"  ok: flops={rec['flops']:.3e} "
                      f"coll_wire={rec['wire_bytes']:.3e} "
                      f"lower={rec['t_lower_s']}s compile={rec['t_compile_s']}s",
                      flush=True)
            except Exception as e:
                rec = {"arch": arch, "shape": shape.name, "status": "fail",
                       "error": "".join(traceback.format_exception(e))[-4000:]}
                print(f"  FAIL: {e}", flush=True)
            with open(path, "w") as fh:
                json.dump(rec, fh, indent=1)
            results.append(rec)
    ok = sum(1 for r in results if r.get("status") == "ok")
    print(f"[dryrun] {ok}/{len(results)} cells ok ({tag})")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--ring-local", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    opts = {"ring_local": args.ring_local} if args.ring_local else {}
    if args.all or args.arch:
        run_all(args.out, multi_pod=args.multi_pod, only_arch=args.arch,
                only_shape=args.shape, opts=opts)
        if args.both_meshes:
            run_all(args.out, multi_pod=True, only_arch=args.arch,
                    only_shape=args.shape, opts=opts)
    else:
        ap.print_help()


if __name__ == "__main__":
    main()
