"""Whole-module analysis of post-SPMD HLO text.

``compiled.cost_analysis()`` counts a ``while`` body **once** (scan-over-
layers would be undercounted by n_layers×), so we analyze the HLO text
ourselves:

  * computations are parsed into blocks; the call graph (``calls=``,
    ``to_apply=``, ``condition=%c, body=%b`` with the
    ``known_trip_count`` backend config) propagates an execution-count
    multiplier from ENTRY;
  * **flops** = Σ over dot ops of 2·numel(result)·prod(lhs contracting
    dims) × multiplier (elementwise/transcendental flops are ignored — on
    matmul-dominated training steps they are ≤1–2 %);
  * **collective bytes** = Σ result bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute × multiplier; wire
    bytes apply the ring-algorithm factor (2× for all-reduce);
  * **hbm bytes** ≈ 2 × Σ result bytes of *materializing* instructions in
    non-fusion computations (×2 models write + subsequent read).
    Non-materializing ops are excluded: ``tuple`` / ``get-tuple-element`` /
    ``parameter`` / ``bitcast`` / ``while`` / ``conditional`` results are
    aliases, and ``dynamic-update-slice`` is counted at the size of its
    *update* operand (in-place on hardware), not the full buffer — without
    these exclusions a scan-over-layers step double-counts its entire carry
    (params + KV caches) once per layer.

All shapes in the SPMD module are already per-device.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "c64": 8, "f32": 4, "s32": 4,
                "u32": 4, "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1,
                "token": 0, "opaque": 0}

_COMP_HEAD = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(")
_INSTR = re.compile(r"^\s+(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_WHILE = re.compile(
    r"while\(.*?condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_TRIP = re.compile(r'known_trip_count..\{"n":"(\d+)"\}')
_CALLS = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_DOT = re.compile(
    r"dot\(\s*%?([\w.\-]+)\s*,\s*%?([\w.\-]+)\s*\).*?"
    r"lhs_contracting_dims=\{([\d,]*)\}")
_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_NO_MATERIALIZE = {"tuple", "get-tuple-element", "parameter", "bitcast",
                   "while", "conditional", "constant", "after-all",
                   "optimization-barrier"}
_OPERANDS_RE = re.compile(r"%([\w.\-]+)")


def _operands(body: str):
    """Operand names of an instruction body like 'opcode(%a, %b, ...)'."""
    inner = body.split("(", 1)[1] if "(" in body else ""
    depth, out, cur = 1, [], ""
    for ch in inner:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        cur += ch
    m = _OPERANDS_RE.findall(cur)
    return m
_ALG_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
               "all-to-all": 1.0, "collective-permute": 1.0}


def _shapes_of(type_str):
    """All array shapes in a result type (handles tuples)."""
    out = []
    for dt, dims in _SHAPE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out.append((dt, n, n * _DTYPE_BYTES[dt]))
    return out


@dataclass
class Computation:
    name: str
    entry: bool = False
    instrs: list = field(default_factory=list)    # (iname, rest_of_line)
    calls: list = field(default_factory=list)     # (callee, mult, kind)
    fusion_internal: bool = False


def _parse(text: str) -> dict:
    comps: dict = {}
    cur = None
    for line in text.splitlines():
        if not line.startswith(" "):
            m = _COMP_HEAD.match(line)
            if m:
                cur = Computation(m.group(2), entry=bool(m.group(1)))
                comps[cur.name] = cur
            continue
        if cur is None:
            continue
        mi = _INSTR.match(line)
        if mi:
            cur.instrs.append((mi.group(1), mi.group(2)))
    # call edges
    for c in comps.values():
        for _, rest in c.instrs:
            mw = _WHILE.search(rest)
            if mw:
                cond, body = mw.groups()
                mt = _TRIP.search(rest)
                trip = int(mt.group(1)) if mt else 1
                c.calls.append((body, trip, "while_body"))
                c.calls.append((cond, trip + 1, "while_cond"))
                continue
            mb = _BRANCHES.search(rest)
            if mb:
                for b in mb.group(1).split(","):
                    b = b.strip().lstrip("%")
                    if b:
                        c.calls.append((b, 1, "branch"))
            for callee in _CALLS.findall(rest):
                kind = "fusion" if "fusion(" in rest or "kind=" in rest \
                    else "call"
                c.calls.append((callee, 1, kind))
    # mark fusion-internal computations (their buffers don't materialize)
    for c in comps.values():
        for callee, _, kind in c.calls:
            if kind == "fusion" and callee in comps:
                comps[callee].fusion_internal = True
    return comps


def _multipliers(comps: dict) -> dict:
    mult = {name: 0.0 for name in comps}
    entry = next((c for c in comps.values() if c.entry), None)
    if entry is None:  # fall back: largest computation
        entry = max(comps.values(), key=lambda c: len(c.instrs))
    stack = [(entry.name, 1.0)]
    while stack:
        name, m = stack.pop()
        if name not in comps:
            continue
        mult[name] += m
        for callee, k, kind in comps[name].calls:
            stack.append((callee, m * k))
    return mult


def _type_prefix(rest: str) -> str:
    """The result-type prefix of an instruction body (handles tuples)."""
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    return rest[:i + 1]
        return rest
    return rest.split(" ", 1)[0]


def analyze_hlo(text: str) -> dict:
    comps = _parse(text)
    mult = _multipliers(comps)

    # instruction-name -> result type string (for dot operand lookup)
    shape_of: dict = {}
    for c in comps.values():
        for iname, rest in c.instrs:
            shape_of[iname] = _type_prefix(rest)

    flops = 0.0
    coll = {k: {"count": 0, "bytes": 0.0} for k in _COLL_KINDS}
    hbm_write = 0.0

    for c in comps.values():
        m = mult.get(c.name, 0.0)
        if m == 0.0:
            continue
        for iname, rest in c.instrs:
            type_part = _type_prefix(rest)
            # dots
            md = _DOT.search(rest)
            if md:
                lhs, _, cdims = md.groups()
                out_shapes = _shapes_of(type_part)
                out_n = out_shapes[0][1] if out_shapes else 0
                lhs_shapes = _SHAPE.findall(shape_of.get(lhs, ""))
                k = 1
                if lhs_shapes:
                    dims = [int(d) for d in lhs_shapes[0][1].split(",") if d]
                    for ci in cdims.split(","):
                        if ci and int(ci) < len(dims):
                            k *= dims[int(ci)]
                flops += m * 2.0 * out_n * k
            # collectives
            for kind in _COLL_KINDS:
                if f" {kind}(" in rest or rest.startswith(f"{kind}("):
                    sz = sum(b for _, _, b in _shapes_of(type_part))
                    coll[kind]["count"] += int(m)
                    coll[kind]["bytes"] += m * sz
                    break
            # hbm writes: materialized buffers in non-fusion comps
            if not c.fusion_internal:
                body = rest[len(type_part):].lstrip()
                opcode = body.split("(", 1)[0].strip().split(" ")[-1]
                if opcode in _NO_MATERIALIZE:
                    continue
                if opcode == "dynamic-update-slice":
                    ops_ = _operands(body)
                    upd = shape_of.get(ops_[1], "") if len(ops_) > 1 else ""
                    hbm_write += m * sum(b for _, _, b in _shapes_of(upd))
                    continue
                hbm_write += m * sum(b for _, _, b in _shapes_of(type_part))

    wire = sum(v["bytes"] * _ALG_FACTOR[k] for k, v in coll.items())
    return {
        "flops": flops,
        "hbm_bytes": 2.0 * hbm_write,
        "collectives": {k: v for k, v in coll.items()},
        "wire_bytes": wire,
        "n_computations": len(comps),
    }
