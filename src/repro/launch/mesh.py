"""Production mesh construction + sharding helpers.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state).  Single-pod: (data=16, model=16) = 256 chips (one v5e
pod); multi-pod: (pod=2, data=16, model=16) = 512 chips.  The ``pod`` axis
composes with ``data`` for gradient reduction (DP spans pod×data) and is the
axis along which the design scales to N pods.
"""
from __future__ import annotations

import jax

from ..core.executor import ShardingRules, params_sharding
from ..core.ir import SystemCatalog

P = jax.sharding.PartitionSpec


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_cpu_mesh(n_data: int = 1, n_model: int = 1):
    """Small mesh over however many (host-platform) devices exist."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def syscat_for_mesh(mesh) -> SystemCatalog:
    return SystemCatalog(mesh_axes=tuple(mesh.axis_names),
                         mesh_shape=tuple(mesh.shape[a]
                                          for a in mesh.axis_names))


def data_spec(mesh) -> P:
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return P(axes if len(axes) > 1 else (axes[0] if axes else None))


def data_axis_size(mesh) -> int:
    """Devices along the ``data`` axis (1 for no mesh / no data axis) —
    the shard count every store partitions over."""
    if mesh is None or "data" not in getattr(mesh, "axis_names", ()):
        return 1
    return int(mesh.shape["data"])


def row_sharding(mesh):
    """NamedSharding for a row/node/doc-partitioned 1-D store array."""
    return jax.sharding.NamedSharding(mesh, P("data"))


def replicated_sharding(mesh):
    return jax.sharding.NamedSharding(mesh, P())


def shard_store_inputs(mesh, values: dict) -> dict:
    """Place store payloads on the mesh: array leaves whose leading dim
    divides the data axis go row-partitioned, everything else replicated.
    Payloads are logically global either way — this only picks device
    placement, so unsharded execution of the same values stays valid."""
    n = data_axis_size(mesh)
    if n <= 1:
        return values
    rs, rep = row_sharding(mesh), replicated_sharding(mesh)

    def place(x):
        try:
            shape = x.shape
        except AttributeError:
            return x
        sh = rs if (len(shape) >= 1 and shape[0] % n == 0) else rep
        return jax.device_put(x, sh)

    return {k: jax.tree.map(place, v) for k, v in values.items()}


def input_shardings(mesh, input_specs: dict) -> dict:
    """Batch-leading inputs shard over (pod, data)."""
    out = {}
    for name, sds in input_specs.items():
        spec = [None] * len(sds.shape)
        if len(sds.shape) >= 1:
            spec[0] = tuple(a for a in ("pod", "data")
                            if a in mesh.axis_names) or None
        out[name] = jax.sharding.NamedSharding(mesh, P(*spec))
    return out


def state_shardings(mesh, model, optimizer, rules=None):
    """NamedShardings for the full TrainState (params + optimizer slots).

    m/v mirror param sharding; Adafactor's factored slots drop the last
    (vr) / second-to-last (vc) dim of the padded param spec; scalars
    replicate."""
    from ..train.train_step import TrainState
    rules = rules or ShardingRules()
    specs = model.param_specs()
    p_shard = params_sharding(specs, mesh, rules)
    abstract = model.abstract_params()
    replicated = jax.sharding.NamedSharding(mesh, P())

    def padded_spec(p_sh, rank):
        s = tuple(p_sh.spec)
        return s + (None,) * (rank - len(s))

    opt_abstract = jax.eval_shape(optimizer.init, abstract)
    if set(opt_abstract) >= {"m", "v", "count"}:
        opt_shard = {"m": p_shard, "v": p_shard, "count": replicated}
        if "master" in opt_abstract:
            opt_shard["master"] = p_shard
    elif set(opt_abstract) == {"slots", "count"}:
        with_master = bool(getattr(optimizer, "master", False))

        def slot(p_sh, p_abs):
            rank = len(p_abs.shape)
            if rank >= 2:
                full = padded_spec(p_sh, rank)
                out = {"vr": jax.sharding.NamedSharding(mesh, P(*full[:-1])),
                       "vc": jax.sharding.NamedSharding(
                           mesh, P(*(full[:-2] + full[-1:])))}
            else:
                out = {"v": p_sh}
            if with_master:
                out["master"] = p_sh
            return out

        opt_shard = {"slots": jax.tree.map(slot, p_shard, abstract),
                     "count": replicated}
    else:
        raise ValueError("unknown optimizer state structure")
    return TrainState(step=replicated, params=p_shard, opt_state=opt_shard)
