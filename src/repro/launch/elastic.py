"""Elastic scaling: re-mesh a training job onto whatever devices remain.

On a real cluster the coordinator detects a lost slice, restarts the job on
N' < N hosts, and this module rebuilds the largest valid (data, model) mesh
from the surviving devices and restores the latest checkpoint *resharded*
onto it (checkpoint.py accepts a different-mesh sharding at restore).

The policy: keep the model axis as large as memory requires (params must
fit), give the rest to data; batch is re-divided across the new data axis
(global batch and the deterministic data stream are unchanged, so training
continues bit-for-bit in sample order).

    mesh = remesh(jax.devices(), min_model=16)
    state = restore_checkpoint(latest, template,
                               shardings=state_shardings(mesh, model, opt))
"""
from __future__ import annotations

import math

import jax


def largest_mesh_shape(n_devices: int, *, min_model: int = 1,
                       prefer_model: int = 16) -> tuple:
    """(data, model) with data*model == largest usable count ≤ n_devices."""
    model = min(prefer_model, n_devices)
    while model >= min_model:
        data = n_devices // model
        if data >= 1 and data * model <= n_devices:
            return (data, model)
        model //= 2
    raise ValueError(f"cannot build a mesh from {n_devices} devices "
                     f"with min_model={min_model}")


def remesh(devices=None, *, min_model: int = 1, prefer_model: int = 16):
    devices = devices if devices is not None else jax.devices()
    data, model = largest_mesh_shape(len(devices), min_model=min_model,
                                     prefer_model=prefer_model)
    used = devices[:data * model]
    import numpy as np
    arr = np.array(used).reshape(data, model)
    return jax.sharding.Mesh(arr, ("data", "model"))


def min_model_axis(param_bytes: float, hbm_bytes: float = 16e9,
                   overhead: float = 3.0) -> int:
    """Smallest power-of-two model axis so params (+optimizer overhead)
    fit per device."""
    need = param_bytes * overhead / hbm_bytes
    m = 1
    while m < need:
        m *= 2
    return m
