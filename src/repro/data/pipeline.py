"""Data pipeline: deterministic synthetic token stream with host prefetch.

Determinism is the fault-tolerance contract: batch(step) is a pure function
of (seed, step), so a restart from checkpoint step k replays exactly the
same stream — no shard bookkeeping needed, and elastic re-sharding keeps
sample order (batch elements are indexed globally, sliced per host).

A background thread keeps ``prefetch`` batches ready (double buffering) so
host batch synthesis overlaps device compute — the SS-chain streaming of the
paper's buffering mechanism applied at the input edge.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    frontend_tokens: int = 0      # multimodal prefix supplied as embeddings
    d_model: int = 0
    encdec: bool = False
    dtype: str = "float32"


def _rng_for(seed: int, step: int) -> np.random.Generator:
    return np.random.Generator(np.random.Philox(key=seed, counter=step))


def synth_batch(cfg: DataConfig, step: int) -> dict:
    """Pure function of (seed, step) -> batch dict matching input_specs."""
    rng = _rng_for(cfg.seed, step)
    b = cfg.global_batch
    s_text = cfg.seq_len - (0 if cfg.encdec else cfg.frontend_tokens)
    # Markov-ish stream: correlated tokens so the loss actually decreases
    base = rng.integers(0, cfg.vocab, size=(b, 1), dtype=np.int32)
    drift = rng.integers(0, 7, size=(b, s_text), dtype=np.int32)
    tokens = (base + np.cumsum(drift, axis=1)) % cfg.vocab
    labels = np.roll(tokens, -1, axis=1)
    labels[:, -1] = -100
    out = {"tokens": tokens.astype(np.int32)}
    full_labels = labels
    if cfg.frontend_tokens and not cfg.encdec:
        emb = rng.standard_normal(
            (b, cfg.frontend_tokens, cfg.d_model)).astype(np.float32) * 0.02
        out["frontend_embeds"] = emb.astype(cfg.dtype)
        pad = np.full((b, cfg.frontend_tokens), -100, np.int32)
        full_labels = np.concatenate([pad, labels], axis=1)
    if cfg.encdec:
        emb = rng.standard_normal(
            (b, cfg.seq_len, cfg.d_model)).astype(np.float32) * 0.02
        out["frontend_embeds"] = emb.astype(cfg.dtype)
    out["labels"] = full_labels.astype(np.int32)
    return out


class PrefetchPipeline:
    """Background-thread prefetch of deterministic batches."""

    def __init__(self, cfg: DataConfig, start_step: int = 0,
                 prefetch: int = 2):
        self.cfg = cfg
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = synth_batch(self.cfg, step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
