"""Batched serving example: prefill a batch of prompts, decode with KV
caches (ring-buffer caches for gemma3's sliding-window layers).

    PYTHONPATH=src python examples/serve_batched.py
"""
from repro.launch import serve as serve_mod


def main():
    print("== gemma3 (local:global attention, ring-buffer local caches) ==")
    serve_mod.main(["--arch", "gemma3-27b", "--smoke", "--batch", "2",
                    "--prompt-len", "12", "--gen", "12", "--ring-local"])
    print("\n== rwkv6 (attention-free, O(1) state) ==")
    serve_mod.main(["--arch", "rwkv6-3b", "--smoke", "--batch", "2",
                    "--prompt-len", "12", "--gen", "12"])


if __name__ == "__main__":
    main()
