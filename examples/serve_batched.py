"""Batched serving example over the async runtime: mixed-length prompts,
bucketed admission, continuous batching, plan-seeded KV pool (qwen3's dense
attention path) and the decode-replay fallback (rwkv's recurrent state).

    PYTHONPATH=src python examples/serve_batched.py
"""
from repro.launch import serve as serve_mod


def main():
    print("== qwen3 (dense GQA: planned prefill seeds the KV pool) ==")
    serve_mod.main(["--arch", "qwen3-0.6b", "--smoke", "--requests", "6",
                    "--prompt-lens", "5,12,8", "--gen", "12",
                    "--max-batch", "3", "--max-seq", "64"])
    print("\n== rwkv6 (attention-free, O(1) state: replay fallback) ==")
    serve_mod.main(["--arch", "rwkv6-3b", "--smoke", "--requests", "4",
                    "--prompt-lens", "6,10", "--gen", "10",
                    "--max-batch", "2", "--max-seq", "64"])


if __name__ == "__main__":
    main()
