"""Async serving example: drive the runtime from asyncio directly, with
staggered arrivals — prefill of late arrivals interleaves with decode of
in-flight requests at token boundaries (continuous batching).

    PYTHONPATH=src python examples/serve_async.py
"""
import asyncio

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.serving import AsyncServingRuntime, ServeRequest


async def main_async():
    cfg = get_smoke_config("qwen3-0.6b").replace(dtype="float32")
    model = build_model(cfg)
    params, _ = model.init_params(jax.random.key(0))
    rng = np.random.RandomState(0)

    rt = AsyncServingRuntime(model, params, max_batch=4, max_seq=64)
    lens = [5, 12, 8, 20, 16, 3]
    rt.warmup(lens)

    # staggered arrivals: 20 ms apart — later requests are admitted and
    # prefilled while earlier ones are mid-decode, joining at the next
    # token boundary
    reqs = [ServeRequest(i, tuple(rng.randint(0, cfg.vocab, n).tolist()),
                         gen=16, arrival=0.02 * i)
            for i, n in enumerate(lens)]
    results = await rt.run(reqs)

    for r in results:
        m = r.metrics
        print(f"req {r.rid}: bucket {m.bucket:3d} "
              f"ttft {m.ttft_s * 1e3:6.1f} ms  "
              f"tpot {m.tpot_s * 1e3:5.2f} ms/tok  tokens {r.tokens[:6]}...")
    print(rt.metrics.report())


if __name__ == "__main__":
    asyncio.run(main_async())
