"""End-to-end training example with checkpoint/resume and the fault-tolerant
loop.  Default is CPU-sized; ``--model-100m`` trains a ~100M-param qwen3-
family config (the full production configs are exercised by the dry-run).

    PYTHONPATH=src python examples/train_lm.py --steps 200
    PYTHONPATH=src python examples/train_lm.py --model-100m --steps 300
"""
import argparse
import sys

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--model-100m", action="store_true")
    args = ap.parse_args()

    if args.model_100m:
        # ~100M params: 12L, d=768, untied head — real work on CPU; expect
        # minutes/step at batch 4 x seq 256.
        import repro.configs.qwen3_0_6b as q
        cfg_100m = q.CONFIG.replace(
            name="qwen3-100m", n_layers=12, d_model=768, heads=12,
            kv_heads=4, head_dim=64, d_ff=2048, vocab=32000,
            dtype="float32")
        q.SMOKE = cfg_100m          # route through --smoke machinery
        train_mod.main(["--arch", "qwen3-0.6b", "--smoke",
                        "--steps", str(args.steps),
                        "--batch", "4", "--seq", "256",
                        "--ckpt-dir", "checkpoints/qwen3-100m"])
    else:
        train_mod.main(["--arch", "qwen3-0.6b", "--smoke",
                        "--steps", str(args.steps),
                        "--batch", "8", "--seq", "64"])


if __name__ == "__main__":
    main()
