"""Tri-model analysis: one ADIL program over table + graph + corpus.

The paper's headline scenario (PoliSci, Fig. 1): a single analysis scans a
tweet relation, walks a graph, and ranks by text relevance, and the
optimizer plans the cross-engine movement.  This example is the literal
reproduction — a *textual* ADIL script declaring the three native store
types and piping them through one `PlanPipeline` plan:

  1. relational: scan the tweet table, filter on engagement, aggregate
     hashtag counts (the frontier seed);
  2. graph: 2-hop expansion over the hashtag co-mention graph, then
     personalized PageRank (topic authority);
  3. text: top-k TF-IDF docs for a query, joined back to tweets and
     aggregated per hashtag (text relevance);
  4. fused ranking = PageRank + text relevance.

Every engine boundary is an explicit ``xfer`` node whose materialization
the cost model decides (pin = stay in device memory — the AWESOME
in-memory optimization; spill = host round-trip, what a naive federated
mediator would do).  Run it and read the EXPLAIN report: the planner pins
every boundary and picks the Pallas frontier kernels over the segment_sum
fallback.

    PYTHONPATH=src python examples/tri_model_analysis.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core.adil_parser import parse_adil
from repro.core.ir import SystemCatalog, standard_catalog
from repro.stores import ColumnStore, GraphStore, TextStore, store_engines


def build_social_data(rng, *, users=500, hashtags=128, tweets=20_000,
                      vocab=256):
    """Synthetic social-media slice: a tweet table, a hashtag co-mention
    graph, and the tweet-text corpus (one doc per tweet)."""
    user = rng.randint(0, users, tweets).astype(np.int32)
    tag = (rng.zipf(1.3, tweets) % hashtags).astype(np.int32)
    doc = np.arange(tweets, dtype=np.int32)
    engagement = (rng.gamma(2.0, 12.0, tweets)).astype(np.float32)
    table = ColumnStore({"user": user, "hashtag": tag, "doc": doc,
                         "engagement": engagement})

    # co-mention edges: tweets by the same user mentioning different tags
    order = np.argsort(user, kind="stable")
    u_sorted, t_sorted = user[order], tag[order]
    same_user = u_sorted[1:] == u_sorted[:-1]
    diff_tag = t_sorted[1:] != t_sorted[:-1]
    sel = same_user & diff_tag
    graph = GraphStore.from_edges(t_sorted[:-1][sel], t_sorted[1:][sel],
                                  hashtags, symmetric=True)

    lens = rng.randint(3, 12, tweets)
    flat = (rng.zipf(1.4, int(lens.sum())) % vocab).astype(np.int64)
    docs = np.split(flat, np.cumsum(lens)[:-1])
    corpus = TextStore.from_docs(docs, vocab)
    return table, graph, corpus


def adil_script(table, graph, corpus):
    t = table.type
    cols = ", ".join(f"[{n}, {d}]" for n, d in t.columns)
    return f"""
USE socialDB;
create analysis hashtag_pulse as {{
  tweets := table(rows={t.rows}, cols=[{cols}]);
  g      := graph(nodes={graph.type.nodes}, edges={graph.type.edges});
  cx     := corpus(docs={corpus.type.docs}, vocab={corpus.type.vocab},
                   postings={corpus.type.postings});
  q      := input([{corpus.type.vocab}], float32, dims=[vocab]);

  t      := rel_scan(tweets);
  hot    := rel_filter(t, col=engagement, cmp=ge, value=30.0);
  seeds  := rel_group_agg(hot, key=hashtag, num_groups={graph.type.nodes},
                          aggs=[[seed, count, hashtag]]);
  sv     := col_tensor(seeds, col=seed, dim=nodes);

  fr     := graph_expand(g, sv, hops=2);
  pr     := graph_pagerank(g, fr, iters=8, damping=0.85);

  hits   := text_topk(cx, q, k=64);
  j      := rel_join(hits, tweets, left_on=doc, right_on=doc);
  trel   := rel_group_agg(j, key=hashtag, num_groups={graph.type.nodes},
                          aggs=[[textrel, sum, score]]);
  tv     := col_tensor(trel, col=textrel, dim=nodes);

  score  := residual_add(pr, tv);
  store(score);
}}
"""


def main():
    rng = np.random.RandomState(0)
    table, graph, corpus = build_social_data(rng)
    cat = standard_catalog()
    analysis = parse_adil(adil_script(table, graph, corpus), cat)

    fn = analysis.compile(SystemCatalog(), engines=store_engines(pallas=True))
    print(fn.explain())
    print()

    query = jnp.asarray(corpus.query_vector(rng.randint(0, 256, 6)))
    score = fn({}, {"tweets": table.payload(), "g": graph.payload(),
                    "cx": corpus.payload(), "q": query})
    top = np.argsort(-np.asarray(score))[:10]
    print("top hashtags (pagerank + text relevance):")
    for h in top:
        print(f"  #{h:<6} score={float(score[h]):.4f}")
    xfers = [r for r in fn.report if r["pattern"] == "xfer_op"]
    pins = sum(1 for r in xfers if r["chosen"] == "xfer_pin")
    print(f"\ncross-engine boundaries: {len(xfers)}, pinned in device "
          f"memory: {pins} (planned placement; the naive baseline would "
          f"spill each through the host)")


if __name__ == "__main__":
    main()
