"""Quickstart: define a model config, let the AWESOME planner pick physical
plans, and take a few training steps on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core.executor import plan_and_compile
from repro.core.ir import SystemCatalog
from repro.data.pipeline import DataConfig, synth_batch
from repro.models import build_model
from repro.models.lm import CATALOG
from repro.train.optim import cosine_schedule, make_optimizer
from repro.train.train_step import init_state, make_train_step


def main():
    cfg = get_smoke_config("gemma3-27b").replace(dtype="float32")
    model = build_model(cfg)
    b, s = 4, 32

    # 1. the workload's logical plan (ADIL analysis block)
    plan = model.build_plan(b, s, mode="train")
    print(f"logical plan: {len(plan)} nodes "
          f"(+{sum(len(n.subplan) for n in plan.topo() if n.subplan)} in "
          f"scan subplans)")

    # 2. the staged plan pipeline: rewrite -> candidates -> cost-model
    # selection -> data parallelism, with both engines offered
    fwd = plan_and_compile(plan, CATALOG, SystemCatalog(),
                           engines=("xla", "pallas"))
    print(fwd.explain())
    for r in fwd.report:
        print(f"virtual node [{r['pattern']}] -> {r['chosen']} "
              f"(costs: { {k: f'{v:.2e}' for k, v in r['costs'].items()} })")

    # 3. train
    opt = make_optimizer("adamw", cosine_schedule(3e-3, 5, 100))
    step = jax.jit(make_train_step(fwd, opt, grad_dtype="float32"))
    params, _ = model.init_params(jax.random.key(0))
    state = init_state(params, opt)
    dc = DataConfig(vocab=cfg.vocab, seq_len=s, global_batch=b)
    for i in range(20):
        batch = {k: jnp.asarray(v) for k, v in synth_batch(dc, i).items()}
        state, m = step(state, batch)
        if i % 5 == 0:
            print(f"step {i:3d}  loss {float(m['loss']):.4f}")
    print("done.")


if __name__ == "__main__":
    main()
