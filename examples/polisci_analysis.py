"""The PoliSci workload pattern (paper Fig. 1/3), in the ADIL-style builder.

The paper's PoliSci pipes a Solr text query into NER, joins against a
Postgres relation, and queries a Neo4j graph.  The tensor-world analogue
composes heterogeneous *engines* the same way: embed (lookup engine) →
attention blocks (the planner chooses full/banded/flash per the cost
model) → head.  What the example demonstrates is the paper's core loop:
one logical analysis, multiple candidate physical plans per virtual node,
learned-cost argmin at sizes-known time.

    PYTHONPATH=src python examples/polisci_analysis.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.adil import Analysis
from repro.core.ir import SystemCatalog, TensorT, standard_catalog
from repro.layers.common import KeyGen
from repro.layers import attention as A
from repro.layers import mlp as F


def main():
    cat = standard_catalog()
    b, s, e = 2, 64, 32

    with Analysis("polisci", cat) as a:
        toks = a.input("tokens", TensorT((b, s), "int32", ("batch", "seq")))
        h = a.op("embed", toks, vocab=512, embed=e, pp=("embed",),
                 dtype="float32")
        # "query the text store": long-context attention — the planner must
        # choose between full / banded / flash engines
        h = a.op("attention", h, heads=4, kv_heads=2, head_dim=8, embed=e,
                 window=16, pp=("attn",))
        # "join with the relation": an MLP mixing step
        h = a.op("mlp", h, ffn=64, embed=e, pp=("mlp",))
        # "aggregate pagerank per topic": reduce over the feature axis via
        # the loss head (scalar summary)
        logits = a.op("unembed", h, vocab=512, pp=("embed",))
        a.store(logits)

    fn = a.compile(SystemCatalog(), engines=("xla", "pallas"))
    print("planner decisions (virtual node -> chosen engine):")
    for r in fn.report:
        print(f"  [{r['pattern']}] -> {r['chosen']}   "
              f"costs={ {k: f'{v:.2e}' for k, v in r['costs'].items()} }")

    kg = KeyGen(jax.random.key(0))
    params = {
        "embed": {"table": jax.random.normal(kg(), (512, e)) * 0.02},
        "attn": A.init_attention(kg, {"embed": e, "heads": 4, "kv_heads": 2,
                                      "head_dim": 8})[0],
        "mlp": F.init_mlp(kg, {"embed": e, "ffn": 64})[0],
    }
    tokens = jnp.asarray(np.random.RandomState(0).randint(0, 512, (b, s)),
                         jnp.int32)
    out = fn(params, {"tokens": tokens})
    print(f"analysis output: shape={out.shape} finite="
          f"{bool(jnp.all(jnp.isfinite(out)))}")


if __name__ == "__main__":
    main()
